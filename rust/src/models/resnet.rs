//! ResNet-50 (He et al. 2016): bottleneck residual blocks [3, 4, 6, 3]
//! with batch normalization. ≈ 25.6 M parameters.

use super::{Model, Phase};
use crate::graph::layers::GraphBuilder;
use crate::graph::shapes::DType;
use crate::graph::{Graph, TensorId};
use crate::util::rng::Pcg32;

pub struct ResNet50;

/// conv → BN → (optional) ReLU.
fn conv_bn(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    ch: usize,
    k: usize,
    s: usize,
    p: usize,
    relu: bool,
) -> TensorId {
    let c = b.conv2d(&format!("{name}.conv"), x, ch, k, s, p);
    let n = b.batch_norm(&format!("{name}.bn"), c);
    if relu {
        b.relu(&format!("{name}.relu"), n)
    } else {
        n
    }
}

/// Bottleneck block: 1×1(mid, stride) → 3×3(mid) → 1×1(out), with a
/// projection shortcut when the shape changes.
fn bottleneck(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    mid: usize,
    out: usize,
    stride: usize,
) -> TensorId {
    let in_ch = b.shape_of(x).dims()[1];
    let c1 = conv_bn(b, &format!("{name}.a"), x, mid, 1, stride, 0, true);
    let c2 = conv_bn(b, &format!("{name}.b"), c1, mid, 3, 1, 1, true);
    let c3 = conv_bn(b, &format!("{name}.c"), c2, out, 1, 1, 0, false);
    let shortcut = if in_ch != out || stride != 1 {
        conv_bn(b, &format!("{name}.proj"), x, out, 1, stride, 0, false)
    } else {
        x
    };
    let sum = b.add(&format!("{name}.add"), c3, shortcut);
    b.relu(&format!("{name}.relu"), sum)
}

/// A stage of `n` bottlenecks; the first downsamples by `stride`.
fn stage(
    b: &mut GraphBuilder,
    name: &str,
    mut x: TensorId,
    n: usize,
    mid: usize,
    out: usize,
    stride: usize,
) -> TensorId {
    for i in 0..n {
        let s = if i == 0 { stride } else { 1 };
        x = bottleneck(b, &format!("{name}.{i}"), x, mid, out, s);
    }
    x
}

impl Model for ResNet50 {
    fn name(&self) -> &'static str {
        "resnet50"
    }

    fn build(&self, phase: Phase, batch: u32, _rng: &mut Pcg32) -> Graph {
        let training = phase == Phase::Training;
        let mut b = GraphBuilder::new(DType::F32);
        let x = b.input("data", &[batch as usize, 3, 224, 224]);

        let stem = conv_bn(&mut b, "conv1", x, 64, 7, 2, 3, true); // 112
        let p1 = b.max_pool("pool1", stem, 3, 2, 1); // 56

        let s1 = stage(&mut b, "res2", p1, 3, 64, 256, 1); // 56
        let s2 = stage(&mut b, "res3", s1, 4, 128, 512, 2); // 28
        let s3 = stage(&mut b, "res4", s2, 6, 256, 1024, 2); // 14
        let s4 = stage(&mut b, "res5", s3, 3, 512, 2048, 2); // 7

        let gap = b.global_avg_pool("gap", s4);
        let f = b.linear("fc", gap, 1000);
        let out = if training {
            b.softmax_loss("loss", f)
        } else {
            b.softmax("prob", f)
        };
        b.finish(vec![out])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::schedule;
    use crate::util::humansize::GIB;

    #[test]
    fn parameter_count_matches_published() {
        let g = ResNet50.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let m = g.param_count() as f64 / 1e6;
        assert!((25.0..26.5).contains(&m), "got {m} M params");
    }

    #[test]
    fn depth_is_50_convs() {
        let g = ResNet50.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let convs = g
            .nodes
            .iter()
            .filter(|n| n.op == crate::graph::OpKind::Conv2d)
            .count();
        // 49 in the main path + 1 fc = ResNet-*50*; projection shortcuts
        // add 4 more convs.
        assert_eq!(convs, 49 + 4);
    }

    #[test]
    fn final_feature_map_is_7x7x2048() {
        let g = ResNet50.build(Phase::Inference, 2, &mut Pcg32::seeded(0));
        let last_relu = g
            .tensors
            .iter()
            .find(|t| t.name == "res5.2.relu")
            .unwrap();
        assert_eq!(last_relu.shape.dims(), &[2, 2048, 7, 7]);
    }

    #[test]
    fn training_schedule_peak_is_plausible() {
        // Training at batch 32 keeps multi-GiB of activations live.
        let g = ResNet50.build(Phase::Training, 32, &mut Pcg32::seeded(0));
        let s = schedule::build(&g, Phase::Training);
        let peak = s.validate().unwrap();
        assert!(
            peak > 3 * GIB / 2 && peak < 16 * GIB,
            "peak {} out of expected range",
            peak
        );
    }

    #[test]
    fn flops_magnitude() {
        // ResNet-50 forward ≈ 3.8–4.1 GFLOP (2×MACs) per 224×224 image.
        let g = ResNet50.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let gf = g.forward_flops() as f64 / 1e9;
        assert!((7.0..9.0).contains(&gf), "got {gf} GFLOP");
    }
}
