//! GoogLeNet / Inception-v1 (Szegedy et al. 2015), after Chainer's
//! `googlenet.py`: LRN stem, nine inception modules, and — in training —
//! the two auxiliary classifier heads. ≈ 13.4 M parameters with aux heads
//! (≈ 7 M for the inference graph, matching the published main column).

use super::{Model, Phase};
use crate::graph::layers::GraphBuilder;
use crate::graph::shapes::DType;
use crate::graph::{Graph, TensorId};
use crate::util::rng::Pcg32;

pub struct GoogLeNet;

/// One inception module: 1×1, 3×3 (reduced), 5×5 (reduced), pool-proj.
#[allow(clippy::too_many_arguments)]
fn inception(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    c1: usize,
    c3r: usize,
    c3: usize,
    c5r: usize,
    c5: usize,
    pp: usize,
) -> TensorId {
    let b1 = {
        let c = b.conv2d(&format!("{name}.1x1"), x, c1, 1, 1, 0);
        b.relu(&format!("{name}.relu1"), c)
    };
    let b3 = {
        let r = b.conv2d(&format!("{name}.3x3r"), x, c3r, 1, 1, 0);
        let r = b.relu(&format!("{name}.relu3r"), r);
        let c = b.conv2d(&format!("{name}.3x3"), r, c3, 3, 1, 1);
        b.relu(&format!("{name}.relu3"), c)
    };
    let b5 = {
        let r = b.conv2d(&format!("{name}.5x5r"), x, c5r, 1, 1, 0);
        let r = b.relu(&format!("{name}.relu5r"), r);
        let c = b.conv2d(&format!("{name}.5x5"), r, c5, 5, 1, 2);
        b.relu(&format!("{name}.relu5"), c)
    };
    let bp = {
        let p = b.max_pool_ceil(&format!("{name}.pool"), x, 3, 1, 1);
        let c = b.conv2d(&format!("{name}.proj"), p, pp, 1, 1, 0);
        b.relu(&format!("{name}.relup"), c)
    };
    b.concat(&format!("{name}.cat"), &[b1, b3, b5, bp])
}

/// Auxiliary classifier head (training only).
fn aux_head(b: &mut GraphBuilder, name: &str, x: TensorId) -> TensorId {
    let p = b.avg_pool(&format!("{name}.pool"), x, 5, 3, 0);
    let c = b.conv2d(&format!("{name}.conv"), p, 128, 1, 1, 0);
    let r = b.relu(&format!("{name}.relu"), c);
    let f1 = b.linear(&format!("{name}.fc1"), r, 1024);
    let r1 = b.relu(&format!("{name}.relu1"), f1);
    let d = b.dropout(&format!("{name}.drop"), r1);
    let f2 = b.linear(&format!("{name}.fc2"), d, 1000);
    b.softmax_loss(&format!("{name}.loss"), f2)
}

impl Model for GoogLeNet {
    fn name(&self) -> &'static str {
        "googlenet"
    }

    fn build(&self, phase: Phase, batch: u32, _rng: &mut Pcg32) -> Graph {
        let training = phase == Phase::Training;
        let mut b = GraphBuilder::new(DType::F32);
        let x = b.input("data", &[batch as usize, 3, 224, 224]);

        // Stem.
        let c1 = b.conv2d("conv1", x, 64, 7, 2, 3); // 112
        let r1 = b.relu("relu1", c1);
        let p1 = b.max_pool_ceil("pool1", r1, 3, 2, 0); // 56
        let n1 = b.lrn("norm1", p1);
        let c2r = b.conv2d("conv2r", n1, 64, 1, 1, 0);
        let r2r = b.relu("relu2r", c2r);
        let c2 = b.conv2d("conv2", r2r, 192, 3, 1, 1);
        let r2 = b.relu("relu2", c2);
        let n2 = b.lrn("norm2", r2);
        let p2 = b.max_pool_ceil("pool2", n2, 3, 2, 0); // 28

        // Inception 3.
        let i3a = inception(&mut b, "inc3a", p2, 64, 96, 128, 16, 32, 32);
        let i3b = inception(&mut b, "inc3b", i3a, 128, 128, 192, 32, 96, 64);
        let p3 = b.max_pool_ceil("pool3", i3b, 3, 2, 0); // 14

        // Inception 4 (+aux heads at 4a and 4d in training).
        let i4a = inception(&mut b, "inc4a", p3, 192, 96, 208, 16, 48, 64);
        let aux1 = training.then(|| aux_head(&mut b, "aux1", i4a));
        let i4b = inception(&mut b, "inc4b", i4a, 160, 112, 224, 24, 64, 64);
        let i4c = inception(&mut b, "inc4c", i4b, 128, 128, 256, 24, 64, 64);
        let i4d = inception(&mut b, "inc4d", i4c, 112, 144, 288, 32, 64, 64);
        let aux2 = training.then(|| aux_head(&mut b, "aux2", i4d));
        let i4e = inception(&mut b, "inc4e", i4d, 256, 160, 320, 32, 128, 128);
        let p4 = b.max_pool_ceil("pool4", i4e, 3, 2, 0); // 7

        // Inception 5 + head.
        let i5a = inception(&mut b, "inc5a", p4, 256, 160, 320, 32, 128, 128);
        let i5b = inception(&mut b, "inc5b", i5a, 384, 192, 384, 48, 128, 128);
        let gap = b.global_avg_pool("gap", i5b);
        let head = if training {
            let d = b.dropout("drop", gap);
            let f = b.linear("fc", d, 1000);
            b.softmax_loss("loss", f)
        } else {
            let f = b.linear("fc", gap, 1000);
            b.softmax("prob", f)
        };

        let mut outputs = vec![head];
        outputs.extend(aux1);
        outputs.extend(aux2);
        b.finish(outputs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::schedule;

    #[test]
    fn inference_parameter_count_matches_published() {
        let g = GoogLeNet.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let m = g.param_count() as f64 / 1e6;
        // Published GoogLeNet main column: ~7.0 M params.
        assert!((6.0..8.0).contains(&m), "got {m} M params");
    }

    #[test]
    fn training_adds_aux_heads() {
        let g = GoogLeNet.build(Phase::Training, 32, &mut Pcg32::seeded(0));
        assert_eq!(g.outputs.len(), 3, "main + two aux losses");
        let m = g.param_count() as f64 / 1e6;
        assert!((12.0..15.0).contains(&m), "got {m} M params with aux");
    }

    #[test]
    fn spatial_pyramid_is_correct() {
        // The final inception output must be 7×7×1024.
        let g = GoogLeNet.build(Phase::Inference, 2, &mut Pcg32::seeded(0));
        let i5b_cat = g
            .tensors
            .iter()
            .find(|t| t.name == "inc5b.cat")
            .expect("inc5b.cat");
        assert_eq!(i5b_cat.shape.dims(), &[2, 1024, 7, 7]);
    }

    #[test]
    fn schedules_validate_both_phases() {
        for phase in [Phase::Training, Phase::Inference] {
            let g = GoogLeNet.build(phase, 8, &mut Pcg32::seeded(0));
            g.validate().unwrap();
            schedule::build(&g, phase).validate().unwrap();
        }
    }
}
