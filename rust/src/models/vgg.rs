//! VGG-16 (Simonyan & Zisserman 2015) — an *extension* model beyond the
//! paper's evaluated five. Its memory profile is the opposite extreme of
//! Inception-ResNet: very few, very large blocks (the 224×224×64 early
//! activations and the 102M-parameter fc6), making it a useful stress of
//! the packing heuristic's behaviour on few-large-rectangle instances.
//! ≈ 138 M parameters.

use super::{Model, Phase};
use crate::graph::layers::GraphBuilder;
use crate::graph::shapes::DType;
use crate::graph::{Graph, TensorId};
use crate::util::rng::Pcg32;

pub struct Vgg16;

fn block(b: &mut GraphBuilder, name: &str, mut x: TensorId, convs: usize, ch: usize) -> TensorId {
    for i in 0..convs {
        let c = b.conv2d(&format!("{name}.conv{i}"), x, ch, 3, 1, 1);
        x = b.relu(&format!("{name}.relu{i}"), c);
    }
    b.max_pool(&format!("{name}.pool"), x, 2, 2, 0)
}

impl Model for Vgg16 {
    fn name(&self) -> &'static str {
        "vgg16"
    }

    fn build(&self, phase: Phase, batch: u32, _rng: &mut Pcg32) -> Graph {
        let training = phase == Phase::Training;
        let mut b = GraphBuilder::new(DType::F32);
        let x = b.input("data", &[batch as usize, 3, 224, 224]);

        let s1 = block(&mut b, "b1", x, 2, 64); // 112
        let s2 = block(&mut b, "b2", s1, 2, 128); // 56
        let s3 = block(&mut b, "b3", s2, 3, 256); // 28
        let s4 = block(&mut b, "b4", s3, 3, 512); // 14
        let s5 = block(&mut b, "b5", s4, 3, 512); // 7

        let f6 = b.linear("fc6", s5, 4096);
        let r6 = b.relu("relu6", f6);
        let d6 = if training { b.dropout("drop6", r6) } else { r6 };
        let f7 = b.linear("fc7", d6, 4096);
        let r7 = b.relu("relu7", f7);
        let d7 = if training { b.dropout("drop7", r7) } else { r7 };
        let f8 = b.linear("fc8", d7, 1000);

        let out = if training {
            b.softmax_loss("loss", f8)
        } else {
            b.softmax("prob", f8)
        };
        b.finish(vec![out])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::schedule;

    #[test]
    fn parameter_count_matches_published() {
        let g = Vgg16.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let m = g.param_count() as f64 / 1e6;
        assert!((135.0..141.0).contains(&m), "got {m} M params");
    }

    #[test]
    fn conv_depth_is_13_plus_3_fc() {
        let g = Vgg16.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let convs = g
            .nodes
            .iter()
            .filter(|n| n.op == crate::graph::OpKind::Conv2d)
            .count();
        let fcs = g
            .nodes
            .iter()
            .filter(|n| n.op == crate::graph::OpKind::Linear)
            .count();
        assert_eq!((convs, fcs), (13, 3));
    }

    #[test]
    fn schedules_validate_and_pack() {
        for phase in [Phase::Training, Phase::Inference] {
            let g = Vgg16.build(phase, 8, &mut Pcg32::seeded(0));
            g.validate().unwrap();
            schedule::build(&g, phase).validate().unwrap();
        }
        let inst =
            super::super::trace_for(&Vgg16, Phase::Training, 16).to_dsa_instance();
        let sol = crate::dsa::bestfit::solve(&inst);
        sol.validate(&inst).unwrap();
        assert!(sol.gap_to(inst.lower_bound()) < 0.1);
    }
}
