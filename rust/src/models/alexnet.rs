//! AlexNet (Krizhevsky et al. 2012), after Chainer's `alex.py` — the
//! single-column variant with 227×227 inputs, LRN, and dropout on the
//! fully connected layers. ≈ 62.4 M parameters.

use super::{Model, Phase};
use crate::graph::layers::GraphBuilder;
use crate::graph::shapes::DType;
use crate::graph::Graph;
use crate::util::rng::Pcg32;

pub struct AlexNet;

impl Model for AlexNet {
    fn name(&self) -> &'static str {
        "alexnet"
    }

    fn build(&self, phase: Phase, batch: u32, _rng: &mut Pcg32) -> Graph {
        let training = phase == Phase::Training;
        let mut b = GraphBuilder::new(DType::F32);
        let x = b.input("data", &[batch as usize, 3, 227, 227]);

        let c1 = b.conv2d("conv1", x, 96, 11, 4, 0); // 55×55
        let r1 = b.relu("relu1", c1);
        let n1 = b.lrn("norm1", r1);
        let p1 = b.max_pool("pool1", n1, 3, 2, 0); // 27×27

        let c2 = b.conv2d("conv2", p1, 256, 5, 1, 2);
        let r2 = b.relu("relu2", c2);
        let n2 = b.lrn("norm2", r2);
        let p2 = b.max_pool("pool2", n2, 3, 2, 0); // 13×13

        let c3 = b.conv2d("conv3", p2, 384, 3, 1, 1);
        let r3 = b.relu("relu3", c3);
        let c4 = b.conv2d("conv4", r3, 384, 3, 1, 1);
        let r4 = b.relu("relu4", c4);
        let c5 = b.conv2d("conv5", r4, 256, 3, 1, 1);
        let r5 = b.relu("relu5", c5);
        let p5 = b.max_pool("pool5", r5, 3, 2, 0); // 6×6

        let f6 = b.linear("fc6", p5, 4096);
        let r6 = b.relu("relu6", f6);
        let d6 = if training { b.dropout("drop6", r6) } else { r6 };
        let f7 = b.linear("fc7", d6, 4096);
        let r7 = b.relu("relu7", f7);
        let d7 = if training { b.dropout("drop7", r7) } else { r7 };
        let f8 = b.linear("fc8", d7, 1000);

        let out = if training {
            b.softmax_loss("loss", f8)
        } else {
            b.softmax("prob", f8)
        };
        b.finish(vec![out])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::schedule::{self};
    use crate::util::humansize::MIB;

    #[test]
    fn parameter_count_matches_published() {
        let g = AlexNet.build(Phase::Training, 32, &mut Pcg32::seeded(0));
        let m = g.param_count() as f64 / 1e6;
        // Single-column AlexNet: ≈62.4 M parameters.
        assert!((60.0..65.0).contains(&m), "got {m} M params");
    }

    #[test]
    fn training_graph_validates_and_schedules() {
        let g = AlexNet.build(Phase::Training, 32, &mut Pcg32::seeded(0));
        g.validate().unwrap();
        let s = schedule::build(&g, Phase::Training);
        let peak = s.validate().unwrap();
        // Activations at b32 land in the hundreds-of-MB range.
        assert!(peak > 100 * MIB, "peak {} too small", peak);
    }

    #[test]
    fn inference_has_no_dropout() {
        let g = AlexNet.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        assert!(g.nodes.iter().all(|n| n.name != "drop6"));
    }

    #[test]
    fn flops_magnitude() {
        // Single-image forward ≈ 0.7–1.5 GFLOP·2 (MACs×2) for AlexNet.
        let g = AlexNet.build(Phase::Inference, 1, &mut Pcg32::seeded(0));
        let gf = g.forward_flops() as f64 / 1e9;
        assert!((1.0..4.0).contains(&gf), "got {gf} GFLOP");
    }
}
