//! The *offset line* structure of §3.2.
//!
//! The time axis `[0, horizon)` is partitioned into contiguous segments,
//! each holding the current skyline height (= the lowest free offset over
//! that time span). Invariant: **adjacent segments have different
//! heights**, so the lowest segment's neighbours are strictly higher and a
//! block can be placed on a segment iff its lifetime is contained in the
//! segment's span — exactly the paper's "can be placed at the chosen offset
//! without colliding with memory blocks placed already".
//!
//! Operations mirror Figure 1 of the paper: choose the lowest (leftmost on
//! ties) offset line, place a block on it (splitting the segment), or
//! *lift* the line into its lowest adjacent neighbour when nothing fits.

/// One offset line: skyline height `height` over the time span `[t0, t1)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Seg {
    pub t0: u64,
    pub t1: u64,
    pub height: u64,
}

impl Seg {
    pub fn span(&self) -> u64 {
        self.t1 - self.t0
    }

    /// Is lifetime `[alloc_at, free_at)` contained in this span?
    pub fn contains(&self, alloc_at: u64, free_at: u64) -> bool {
        self.t0 <= alloc_at && free_at <= self.t1
    }
}

/// The skyline: an ordered, contiguous, height-distinct segment list.
#[derive(Debug, Clone)]
pub struct Skyline {
    segs: Vec<Seg>,
}

impl Skyline {
    /// Fresh skyline at height 0 over `[0, horizon)`.
    pub fn new(horizon: u64) -> Skyline {
        assert!(horizon > 0, "empty horizon");
        Skyline {
            segs: vec![Seg {
                t0: 0,
                t1: horizon,
                height: 0,
            }],
        }
    }

    /// Seed a skyline from an explicit segment list — the warm-start
    /// re-solve (`bestfit::resolve`) starts from the envelope of kept
    /// placements instead of a flat line. The list must satisfy the
    /// structural invariants: contiguous cover starting at 0, positive
    /// spans, height-distinct neighbours.
    pub fn from_segments(segs: Vec<Seg>) -> Skyline {
        assert!(!segs.is_empty(), "empty skyline");
        let mut t = 0;
        for (i, s) in segs.iter().enumerate() {
            assert!(
                s.t0 == t && s.t1 > s.t0,
                "segment {i} breaks the contiguous cover"
            );
            if i > 0 {
                assert_ne!(
                    segs[i - 1].height,
                    s.height,
                    "equal heights at segments {} and {i}",
                    i - 1
                );
            }
            t = s.t1;
        }
        Skyline { segs }
    }

    pub fn len(&self) -> usize {
        self.segs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.segs.is_empty()
    }

    pub fn seg(&self, idx: usize) -> Seg {
        self.segs[idx]
    }

    pub fn segments(&self) -> &[Seg] {
        &self.segs
    }

    /// Index of the lowest offset line; leftmost wins ties (§3.2).
    pub fn lowest_leftmost(&self) -> usize {
        let mut best = 0;
        for (i, s) in self.segs.iter().enumerate().skip(1) {
            if s.height < self.segs[best].height {
                best = i;
            }
        }
        best
    }

    /// Skyline height at time `t`.
    pub fn height_at(&self, t: u64) -> u64 {
        match self.segs.binary_search_by(|s| {
            if t < s.t0 {
                std::cmp::Ordering::Greater
            } else if t >= s.t1 {
                std::cmp::Ordering::Less
            } else {
                std::cmp::Ordering::Equal
            }
        }) {
            Ok(i) => self.segs[i].height,
            Err(_) => panic!("height_at({t}) outside horizon"),
        }
    }

    /// Highest offset line — after all placements this equals the packing
    /// peak.
    pub fn max_height(&self) -> u64 {
        self.segs.iter().map(|s| s.height).max().unwrap_or(0)
    }

    /// Place a block with lifetime `[alloc_at, free_at)` and size `size`
    /// on segment `idx`; returns the assigned offset (the segment height).
    /// The lifetime must be contained in the segment span.
    pub fn place(&mut self, idx: usize, alloc_at: u64, free_at: u64, size: u64) -> u64 {
        let seg = self.segs[idx];
        assert!(
            seg.contains(alloc_at, free_at),
            "block [{alloc_at},{free_at}) not contained in segment [{},{})",
            seg.t0,
            seg.t1
        );
        assert!(size > 0);
        let offset = seg.height;
        let raised = Seg {
            t0: alloc_at,
            t1: free_at,
            height: seg.height + size,
        };
        let mut replacement = Vec::with_capacity(3);
        if alloc_at > seg.t0 {
            replacement.push(Seg {
                t0: seg.t0,
                t1: alloc_at,
                height: seg.height,
            });
        }
        replacement.push(raised);
        if free_at < seg.t1 {
            replacement.push(Seg {
                t0: free_at,
                t1: seg.t1,
                height: seg.height,
            });
        }
        self.segs.splice(idx..=idx, replacement);
        self.normalize_around(idx);
        offset
    }

    /// Lift the offset line `idx` into its lowest adjacent neighbour
    /// (both, when they tie) — the §3.2 move used when no unplaced block
    /// fits the chosen line. Panics when the skyline is a single segment
    /// (the caller's search must have found a block in that case, since
    /// every lifetime is contained in the full horizon).
    pub fn lift(&mut self, idx: usize) {
        let left = idx.checked_sub(1).map(|i| self.segs[i].height);
        let right = self.segs.get(idx + 1).map(|s| s.height);
        let target = match (left, right) {
            (Some(l), Some(r)) => l.min(r),
            (Some(l), None) => l,
            (None, Some(r)) => r,
            (None, None) => panic!("lift on a single-segment skyline"),
        };
        debug_assert!(target > self.segs[idx].height, "lift must raise");
        self.segs[idx].height = target;
        self.normalize_around(idx);
    }

    /// Merge equal-height neighbours around position `idx`, restoring the
    /// height-distinct invariant.
    fn normalize_around(&mut self, idx: usize) {
        // Scan a small window; splice may have shifted indices, so clamp.
        let mut i = idx.saturating_sub(1);
        while i + 1 < self.segs.len() {
            if self.segs[i].height == self.segs[i + 1].height {
                self.segs[i].t1 = self.segs[i + 1].t1;
                self.segs.remove(i + 1);
            } else {
                i += 1;
                if i > idx + 3 {
                    break; // outside the affected window
                }
            }
        }
    }

    /// Check structural invariants (used by tests and debug assertions):
    /// contiguous cover, positive spans, height-distinct neighbours.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.segs.is_empty() {
            return Err("empty skyline".into());
        }
        for (i, s) in self.segs.iter().enumerate() {
            if s.t1 <= s.t0 {
                return Err(format!("segment {i} has empty span"));
            }
            if i > 0 {
                let p = &self.segs[i - 1];
                if p.t1 != s.t0 {
                    return Err(format!("gap between segments {} and {i}", i - 1));
                }
                if p.height == s.height {
                    return Err(format!("equal heights at segments {} and {i}", i - 1));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn place_splits_and_returns_offset() {
        let mut sky = Skyline::new(10);
        let off = sky.place(0, 2, 6, 5);
        assert_eq!(off, 0);
        assert_eq!(
            sky.segments(),
            &[
                Seg { t0: 0, t1: 2, height: 0 },
                Seg { t0: 2, t1: 6, height: 5 },
                Seg { t0: 6, t1: 10, height: 0 },
            ]
        );
        sky.check_invariants().unwrap();
    }

    #[test]
    fn place_full_span_no_split() {
        let mut sky = Skyline::new(10);
        sky.place(0, 0, 10, 3);
        assert_eq!(sky.len(), 1);
        assert_eq!(sky.max_height(), 3);
    }

    #[test]
    fn equal_height_neighbours_merge_after_place() {
        let mut sky = Skyline::new(10);
        sky.place(0, 0, 5, 4); // [0,5)@4, [5,10)@0
        let idx = sky.lowest_leftmost();
        assert_eq!(sky.seg(idx).t0, 5);
        sky.place(idx, 5, 10, 4); // both now height 4 → merge to one
        assert_eq!(sky.len(), 1);
        assert_eq!(sky.seg(0), Seg { t0: 0, t1: 10, height: 4 });
    }

    #[test]
    fn lowest_leftmost_prefers_left_on_ties() {
        let mut sky = Skyline::new(12);
        sky.place(0, 4, 8, 2); // [0,4)@0, [4,8)@2, [8,12)@0
        assert_eq!(sky.lowest_leftmost(), 0);
    }

    #[test]
    fn lift_merges_into_lowest_neighbour() {
        let mut sky = Skyline::new(12);
        sky.place(0, 0, 4, 7); // [0,4)@7 [4,12)@0
        let idx = sky.lowest_leftmost();
        sky.place(idx, 8, 12, 3); // [0,4)@7 [4,8)@0 [8,12)@3
        let low = sky.lowest_leftmost();
        assert_eq!(sky.seg(low).height, 0);
        sky.lift(low); // raises [4,8) to min(7,3)=3, merges with right
        sky.check_invariants().unwrap();
        assert_eq!(
            sky.segments(),
            &[Seg { t0: 0, t1: 4, height: 7 }, Seg { t0: 4, t1: 12, height: 3 }]
        );
    }

    #[test]
    fn lift_merges_both_when_neighbours_tie() {
        let mut sky = Skyline::new(12);
        sky.place(0, 0, 4, 5);
        sky.place(sky.lowest_leftmost(), 8, 12, 5);
        // [0,4)@5 [4,8)@0 [8,12)@5
        sky.lift(sky.lowest_leftmost());
        assert_eq!(sky.len(), 1);
        assert_eq!(sky.seg(0).height, 5);
    }

    #[test]
    fn from_segments_seeds_and_operates() {
        let mut sky = Skyline::from_segments(vec![
            Seg { t0: 0, t1: 4, height: 7 },
            Seg { t0: 4, t1: 9, height: 0 },
            Seg { t0: 9, t1: 12, height: 3 },
        ]);
        sky.check_invariants().unwrap();
        let idx = sky.lowest_leftmost();
        assert_eq!(sky.seg(idx).t0, 4);
        let off = sky.place(idx, 4, 9, 3);
        assert_eq!(off, 0, "seeded height is the placement offset");
        // [4,9) raised to 3 merges with [9,12)@3.
        assert_eq!(
            sky.segments(),
            &[Seg { t0: 0, t1: 4, height: 7 }, Seg { t0: 4, t1: 12, height: 3 }]
        );
    }

    #[test]
    #[should_panic(expected = "contiguous cover")]
    fn from_segments_rejects_gaps() {
        let _ = Skyline::from_segments(vec![
            Seg { t0: 0, t1: 4, height: 7 },
            Seg { t0: 5, t1: 9, height: 0 },
        ]);
    }

    #[test]
    #[should_panic(expected = "equal heights")]
    fn from_segments_rejects_equal_neighbours() {
        let _ = Skyline::from_segments(vec![
            Seg { t0: 0, t1: 4, height: 7 },
            Seg { t0: 4, t1: 9, height: 7 },
        ]);
    }

    #[test]
    fn height_at_lookup() {
        let mut sky = Skyline::new(10);
        sky.place(0, 3, 7, 9);
        assert_eq!(sky.height_at(0), 0);
        assert_eq!(sky.height_at(3), 9);
        assert_eq!(sky.height_at(6), 9);
        assert_eq!(sky.height_at(7), 0);
    }

    #[test]
    #[should_panic(expected = "not contained")]
    fn place_outside_span_panics() {
        let mut sky = Skyline::new(10);
        sky.place(0, 0, 5, 1); // [0,5)@1 [5,10)@0
        let idx = sky.lowest_leftmost();
        sky.place(idx, 4, 6, 1); // spans into raised segment
    }

    #[test]
    fn stacking_on_raised_segment() {
        let mut sky = Skyline::new(8);
        sky.place(0, 0, 8, 4);
        let off = sky.place(0, 2, 6, 3);
        assert_eq!(off, 4);
        assert_eq!(sky.max_height(), 7);
        sky.check_invariants().unwrap();
    }
}
