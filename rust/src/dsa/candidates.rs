//! Candidate index for the best-fit solver: per-window unplaced-block
//! sets ordered by the active [`Policy`] key.
//!
//! The reference solver rescans every block whose `alloc_at` falls in the
//! chosen line's window on *every* step — already-placed blocks included
//! — which is where its quadratic constant lives. This index maintains
//! the exact candidate sets instead:
//!
//! * time is partitioned into **windows**, one per skyline segment,
//!   mirrored from the [`IndexedSkyline`](super::indexed::IndexedSkyline)
//!   via its [`Changes`] log;
//! * an unplaced block whose lifetime is contained in a window is
//!   **active** there (windows partition time, so the window is unique),
//!   stored in that window's `BTreeSet` ordered by
//!   [`BlockChoice::order_key`](super::policies::BlockChoice::order_key)
//!   — the set maximum *is* the block the paper's rule places next;
//! * an unplaced block whose lifetime crosses a window boundary fits no
//!   single offset line and is **parked** on one crossed boundary; when a
//!   merge makes that boundary vanish the block either activates in the
//!   merged window or re-parks on one of the merged window's edges (both
//!   still current boundaries strictly inside its lifetime).
//!
//! Each solve step therefore touches only live candidates: `best` is one
//! ordered-set lookup, `place` one removal, and a split/merge
//! redistributes exactly the affected window's blocks.
//!
//! [`Changes`]: super::indexed::Changes

use super::indexed::{ChangeEvent, Changes, Span};
use super::policies::Policy;
use super::problem::DsaInstance;
use super::skyline::Seg;
use std::cmp::Reverse;
use std::collections::{BTreeSet, HashMap};

/// Total preference order under the active policy; the maximal key is
/// the block `BlockChoice::prefer` would choose, and the trailing id
/// makes every key unique.
type CandKey = (u64, u64, Reverse<usize>);

/// Where one unplaced block currently lives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Loc {
    /// Candidate of the window starting at this tick.
    Active(u64),
    /// Lifetime crosses the boundary at this tick.
    Parked(u64),
    Placed,
}

/// The candidate index. Built once per solve (the policy fixes the key
/// order) and kept in lockstep with the skyline's window partition.
#[derive(Debug)]
pub struct CandidateIndex {
    /// Per-block policy key (index = block id).
    keys: Vec<CandKey>,
    /// Per-block lifetime `(alloc_at, free_at)`.
    lifetimes: Vec<(u64, u64)>,
    /// Window start tick → policy-ordered active candidates.
    active: HashMap<u64, BTreeSet<CandKey>>,
    /// Boundary tick → blocks parked on it.
    parked: HashMap<u64, Vec<usize>>,
    loc: Vec<Loc>,
}

impl CandidateIndex {
    /// Index every block of `inst` as active in the full-horizon window
    /// `[0, horizon)` — the fresh skyline's single segment.
    pub fn new(inst: &DsaInstance, policy: Policy) -> CandidateIndex {
        let keys: Vec<CandKey> = inst
            .blocks
            .iter()
            .map(|b| policy.block_choice.order_key(b))
            .collect();
        let lifetimes = inst.blocks.iter().map(|b| (b.alloc_at, b.free_at)).collect();
        let mut active = HashMap::new();
        if !keys.is_empty() {
            active.insert(0, keys.iter().copied().collect::<BTreeSet<CandKey>>());
        }
        CandidateIndex {
            loc: vec![Loc::Active(0); keys.len()],
            keys,
            lifetimes,
            active,
            parked: HashMap::new(),
        }
    }

    /// Index only the listed blocks, distributed over a seeded window
    /// partition (the warm-start re-solve's kept-placement envelope
    /// instead of a fresh single-segment skyline). `windows` must be the
    /// seeded skyline's segments in time order; every listed block's
    /// lifetime must lie inside the covered span. Unlisted blocks are
    /// treated as already placed.
    pub fn with_blocks(
        inst: &DsaInstance,
        policy: Policy,
        ids: &[usize],
        windows: &[Seg],
    ) -> CandidateIndex {
        let keys: Vec<CandKey> = inst
            .blocks
            .iter()
            .map(|b| policy.block_choice.order_key(b))
            .collect();
        let lifetimes: Vec<(u64, u64)> =
            inst.blocks.iter().map(|b| (b.alloc_at, b.free_at)).collect();
        let mut idx = CandidateIndex {
            loc: vec![Loc::Placed; keys.len()],
            keys,
            lifetimes,
            active: HashMap::new(),
            parked: HashMap::new(),
        };
        for &id in ids {
            let (a, f) = idx.lifetimes[id];
            // The window holding the alloc tick; windows partition time.
            let w = windows.partition_point(|s| s.t1 <= a);
            let win = &windows[w];
            debug_assert!(win.t0 <= a && a < win.t1, "alloc tick outside windows");
            if f <= win.t1 {
                idx.active.entry(win.t0).or_default().insert(idx.keys[id]);
                idx.loc[id] = Loc::Active(win.t0);
            } else {
                // Crosses the window's right edge: that edge is a current
                // boundary strictly inside the lifetime.
                idx.parked.entry(win.t1).or_default().push(id);
                idx.loc[id] = Loc::Parked(win.t1);
            }
        }
        idx
    }

    /// The preferred unplaced block of the window starting at
    /// `window_t0`, if any fits it. O(log n).
    pub fn best(&self, window_t0: u64) -> Option<usize> {
        self.active
            .get(&window_t0)
            .and_then(|set| set.iter().next_back())
            .map(|key| key.2 .0)
    }

    /// Mark block `id` placed, removing it from its active window. Must
    /// only be called with ids returned by [`best`](Self::best).
    pub fn place(&mut self, id: usize) {
        match self.loc[id] {
            Loc::Active(t0) => {
                let set = self.active.get_mut(&t0).expect("active window exists");
                let removed = set.remove(&self.keys[id]);
                debug_assert!(removed, "active block missing from its window set");
                if set.is_empty() {
                    self.active.remove(&t0);
                }
            }
            other => panic!("place of non-active block {id}: {other:?}"),
        }
        self.loc[id] = Loc::Placed;
    }

    /// Mirror one `place`/`lift` call's structural skyline changes.
    pub fn apply(&mut self, changes: &Changes) {
        for e in &changes.events {
            match *e {
                ChangeEvent::Split {
                    parent,
                    children,
                    n,
                } => self.on_split(parent, &children[..n]),
                ChangeEvent::Merge { left, right } => self.on_merge(left, right),
            }
        }
    }

    /// A window split: redistribute its candidates over the children;
    /// blocks crossing a fresh internal boundary park there.
    fn on_split(&mut self, parent: Span, children: &[Span]) {
        let Some(set) = self.active.remove(&parent.t0) else {
            return;
        };
        for key in set {
            let id = key.2 .0;
            let (a, f) = self.lifetimes[id];
            match children.iter().find(|c| c.contains(a, f)) {
                Some(c) => {
                    self.active.entry(c.t0).or_default().insert(key);
                    self.loc[id] = Loc::Active(c.t0);
                }
                None => {
                    let bnd = children[..children.len() - 1]
                        .iter()
                        .map(|c| c.t1)
                        .find(|&b| a < b && b < f)
                        .expect("uncontained block must cross an internal boundary");
                    self.parked.entry(bnd).or_default().push(id);
                    self.loc[id] = Loc::Parked(bnd);
                }
            }
        }
    }

    /// A boundary vanished: union the two windows' candidates and revive
    /// (or re-park) the blocks parked on it.
    fn on_merge(&mut self, left: Span, right: Span) {
        let boundary = left.t1;
        debug_assert_eq!(right.t0, boundary, "merge of non-adjacent windows");
        let (lo, hi) = (left.t0, right.t1);
        if let Some(right_set) = self.active.remove(&right.t0) {
            let merged = self.active.entry(lo).or_default();
            for key in right_set {
                self.loc[key.2 .0] = Loc::Active(lo);
                merged.insert(key);
            }
        }
        if let Some(ids) = self.parked.remove(&boundary) {
            for id in ids {
                let (a, f) = self.lifetimes[id];
                if lo <= a && f <= hi {
                    self.active.entry(lo).or_default().insert(self.keys[id]);
                    self.loc[id] = Loc::Active(lo);
                } else {
                    // Still uncontained: the lifetime pokes past an edge
                    // of the merged window, and that edge is a current
                    // boundary strictly inside the lifetime.
                    let bnd = if a < lo { lo } else { hi };
                    debug_assert!(a < bnd && bnd < f, "re-park boundary outside lifetime");
                    self.parked.entry(bnd).or_default().push(id);
                    self.loc[id] = Loc::Parked(bnd);
                }
            }
        }
    }

    /// Number of unplaced blocks still indexed (active + parked).
    pub fn remaining(&self) -> usize {
        self.loc.iter().filter(|l| !matches!(l, Loc::Placed)).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsa::indexed::IndexedSkyline;
    use crate::dsa::policies::BlockChoice;

    fn index_for(triples: &[(u64, u64, u64)]) -> (DsaInstance, CandidateIndex) {
        let inst = DsaInstance::from_triples(triples);
        let idx = CandidateIndex::new(&inst, Policy::default());
        (inst, idx)
    }

    #[test]
    fn initial_best_is_policy_winner() {
        // Longest lifetime wins: block 1 lives [0,10).
        let (_, idx) = index_for(&[(5, 2, 4), (5, 0, 10), (9, 3, 5)]);
        assert_eq!(idx.best(0), Some(1));
        assert_eq!(idx.remaining(), 3);
    }

    #[test]
    fn place_removes_and_reveals_next() {
        let (_, mut idx) = index_for(&[(5, 2, 4), (5, 0, 10)]);
        idx.place(1);
        assert_eq!(idx.best(0), Some(0));
        idx.place(0);
        assert_eq!(idx.best(0), None);
        assert_eq!(idx.remaining(), 0);
    }

    #[test]
    #[should_panic(expected = "non-active")]
    fn double_place_panics() {
        let (_, mut idx) = index_for(&[(5, 0, 4)]);
        idx.place(0);
        idx.place(0);
    }

    #[test]
    fn split_redistributes_and_parks() {
        // Window [0,12) splits at [4,8): block 0 fits left, block 1 fits
        // right, block 2 fits the raised middle, block 3 spans a boundary.
        let (_, mut idx) = index_for(&[(1, 0, 4), (1, 8, 12), (1, 5, 7), (1, 2, 6)]);
        let mut sky = IndexedSkyline::new(12);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 4, 8, 10, &mut ch);
        idx.apply(&ch);
        assert_eq!(idx.best(0), Some(0));
        assert_eq!(idx.best(8), Some(1));
        assert_eq!(idx.best(4), Some(2), "raised window hosts contained blocks");
        assert_eq!(idx.remaining(), 4, "parked block 3 still indexed");
    }

    #[test]
    fn merge_revives_parked_blocks() {
        let (_, mut idx) = index_for(&[(1, 2, 6)]);
        let mut sky = IndexedSkyline::new(12);
        let mut ch = Changes::default();
        // Split at [4,8): block [2,6) crosses boundary 4 → parked.
        sky.place(sky.lowest_leftmost(), 4, 8, 10, &mut ch);
        idx.apply(&ch);
        assert_eq!(idx.best(0), None);
        // Lift [0,4) to height 10: merges with the raised segment, the
        // boundary at 4 vanishes, and [0,8) contains [2,6) again.
        let low = sky.slot_at(0).unwrap();
        sky.lift(low, &mut ch);
        idx.apply(&ch);
        assert_eq!(idx.best(0), Some(0));
        assert_eq!(sky.segments().len(), 2);
    }

    #[test]
    fn with_blocks_seeds_windows_and_parks_crossers() {
        // Windows [0,4) [4,8) [8,12): block 0 fits the first, block 1 the
        // last, block 2 crosses the boundary at 8, block 3 is unlisted.
        let inst =
            DsaInstance::from_triples(&[(1, 0, 4), (1, 8, 12), (1, 5, 10), (1, 0, 2)]);
        let windows = [
            Seg { t0: 0, t1: 4, height: 2 },
            Seg { t0: 4, t1: 8, height: 0 },
            Seg { t0: 8, t1: 12, height: 5 },
        ];
        let mut idx =
            CandidateIndex::with_blocks(&inst, Policy::default(), &[0, 1, 2], &windows);
        assert_eq!(idx.remaining(), 3, "unlisted block 3 is not indexed");
        assert_eq!(idx.best(0), Some(0));
        assert_eq!(idx.best(8), Some(1));
        assert_eq!(idx.best(4), None, "crosser is parked, not active");
        // Lift until the boundary at 8 vanishes: the crosser revives.
        let mut sky = IndexedSkyline::from_segments(&windows);
        let mut ch = Changes::default();
        sky.lift(sky.lowest_leftmost(), &mut ch); // [4,8)@0 → 2, merges left
        idx.apply(&ch);
        assert_eq!(idx.best(0), Some(0), "crosser still parked after left merge");
        sky.lift(sky.lowest_leftmost(), &mut ch); // [0,8)@2 → 5, merges right
        idx.apply(&ch);
        assert_eq!(sky.segments().len(), 1);
        assert_eq!(idx.best(0), Some(2), "revived crosser wins on lifetime");
    }

    #[test]
    fn policy_order_controls_best() {
        let triples = [(100, 0, 2), (1, 0, 9)];
        let inst = DsaInstance::from_triples(&triples);
        let longest = CandidateIndex::new(&inst, Policy::default());
        assert_eq!(longest.best(0), Some(1));
        let largest = CandidateIndex::new(
            &inst,
            Policy {
                block_choice: BlockChoice::LargestSize,
            },
        );
        assert_eq!(largest.best(0), Some(0));
    }
}
