//! Ablatable choice policies for the best-fit heuristic.
//!
//! The paper fixes *block choice* = longest lifetime and *offset choice* =
//! lowest-then-leftmost (§3.2). DESIGN.md calls these design choices out
//! for ablation; `benches/ablations.rs` sweeps them across all model
//! traces to quantify how much each rule matters.

use super::problem::Block;

/// Which block to place on the chosen offset line, among those whose
/// lifetimes fit the line's span.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockChoice {
    /// The paper's rule: longest lifetime first (ties: larger size, then
    /// lower id — deterministic).
    LongestLifetime,
    /// Largest size first (classic decreasing-size packing intuition).
    LargestSize,
    /// Largest area (size × lifetime) first.
    LargestArea,
    /// Profile order: earliest allocation tick first (FIFO-like).
    EarliestAlloc,
}

impl BlockChoice {
    pub const ALL: [BlockChoice; 4] = [
        BlockChoice::LongestLifetime,
        BlockChoice::LargestSize,
        BlockChoice::LargestArea,
        BlockChoice::EarliestAlloc,
    ];

    pub fn name(self) -> &'static str {
        match self {
            BlockChoice::LongestLifetime => "longest-lifetime",
            BlockChoice::LargestSize => "largest-size",
            BlockChoice::LargestArea => "largest-area",
            BlockChoice::EarliestAlloc => "earliest-alloc",
        }
    }

    /// Strict "is `a` preferred over `b`" under this policy.
    pub fn prefer(self, a: &Block, b: &Block) -> bool {
        self.order_key(a) > self.order_key(b)
    }

    /// Total ordering key: `prefer(a, b)` ⇔ `order_key(a) > order_key(b)`.
    /// Lexicographic — primary policy key, then size, then lower id — so
    /// distinct blocks always compare unequal (full determinism). The
    /// indexed solver's candidate sets
    /// ([`CandidateIndex`](super::candidates::CandidateIndex)) are
    /// ordered by this key so the preferred block is the set maximum.
    pub fn order_key(self, b: &Block) -> (u64, u64, std::cmp::Reverse<usize>) {
        (self.key(b), b.size, std::cmp::Reverse(b.id))
    }

    fn key(self, b: &Block) -> u64 {
        match self {
            BlockChoice::LongestLifetime => b.lifetime(),
            BlockChoice::LargestSize => b.size,
            BlockChoice::LargestArea => b.size.saturating_mul(b.lifetime()),
            // Earlier alloc = preferred ⇒ invert for max-comparison.
            BlockChoice::EarliestAlloc => u64::MAX - b.alloc_at,
        }
    }
}

/// Full solver policy (offset choice is structural in the skyline —
/// lowest/leftmost — so only block choice varies today; the struct leaves
/// room for future offset policies without an API break).
#[derive(Debug, Clone, Copy)]
pub struct Policy {
    pub block_choice: BlockChoice,
}

impl Default for Policy {
    /// The paper's configuration.
    fn default() -> Policy {
        Policy {
            block_choice: BlockChoice::LongestLifetime,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blk(id: usize, size: u64, a: u64, f: u64) -> Block {
        Block::new(id, size, a, f)
    }

    #[test]
    fn longest_lifetime_prefers_longer() {
        let p = BlockChoice::LongestLifetime;
        assert!(p.prefer(&blk(0, 1, 0, 10), &blk(1, 100, 0, 5)));
    }

    #[test]
    fn lifetime_tie_broken_by_size_then_id() {
        let p = BlockChoice::LongestLifetime;
        assert!(p.prefer(&blk(0, 9, 0, 5), &blk(1, 3, 0, 5)));
        // Same lifetime and size → lower id preferred.
        assert!(p.prefer(&blk(0, 3, 0, 5), &blk(1, 3, 0, 5)));
        assert!(!p.prefer(&blk(1, 3, 0, 5), &blk(0, 3, 0, 5)));
    }

    #[test]
    fn largest_size_policy() {
        let p = BlockChoice::LargestSize;
        assert!(p.prefer(&blk(0, 100, 0, 2), &blk(1, 1, 0, 50)));
    }

    #[test]
    fn earliest_alloc_policy() {
        let p = BlockChoice::EarliestAlloc;
        assert!(p.prefer(&blk(1, 1, 0, 2), &blk(0, 100, 5, 50)));
    }

    #[test]
    fn preference_is_asymmetric() {
        for policy in BlockChoice::ALL {
            let a = blk(0, 4, 0, 7);
            let b = blk(1, 9, 1, 3);
            assert!(
                policy.prefer(&a, &b) ^ policy.prefer(&b, &a),
                "policy {} must order distinct blocks",
                policy.name()
            );
        }
    }
}
