//! Address-ordered first-fit DSA baseline.
//!
//! Processes blocks in profile (allocation) order and gives each the lowest
//! offset that does not collide with already-placed, lifetime-overlapping
//! blocks. This is the packing an *idealized online* allocator — one with a
//! perfectly compacting free list but no knowledge of the future — would
//! produce, so it separates the benefit of "one arena + offsets" from the
//! benefit of the paper's *offline, lifetime-aware* best-fit ordering.

use super::problem::DsaInstance;
use super::solution::Assignment;

/// Solve by first-fit in allocation order.
pub fn solve(inst: &DsaInstance) -> Assignment {
    let n = inst.len();
    let mut offsets = vec![0u64; n];
    let mut order: Vec<usize> = (0..n).collect();
    // Allocation order; ties (same tick cannot happen — the profiler clock
    // is strictly increasing) are broken by id for robustness on synthetic
    // instances.
    order.sort_unstable_by_key(|&i| (inst.blocks[i].alloc_at, i));

    // Placed blocks kept sorted by alloc tick for the same windowed scan
    // optimization bestfit uses; here a simple live-set filter suffices
    // because first-fit visits blocks in time order.
    let mut placed: Vec<usize> = Vec::new();

    for &i in &order {
        let b = &inst.blocks[i];
        // Collect address intervals of lifetime-overlapping placed blocks.
        let mut busy: Vec<(u64, u64)> = placed
            .iter()
            .map(|&j| &inst.blocks[j])
            .filter(|p| p.overlaps(b))
            .map(|p| (offsets[p.id], offsets[p.id] + p.size))
            .collect();
        busy.sort_unstable();
        // Scan for the first gap of at least b.size.
        let mut candidate = 0u64;
        for (lo, hi) in busy {
            if candidate + b.size <= lo {
                break;
            }
            candidate = candidate.max(hi);
        }
        offsets[i] = candidate;
        placed.push(i);
        // Drop blocks that can never overlap future allocations (their
        // free tick is before this block's alloc tick) — keeps the filter
        // linear in the live set, not in n.
        placed.retain(|&j| inst.blocks[j].free_at > b.alloc_at);
    }

    Assignment::from_offsets(inst, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn serial_blocks_reuse_offset_zero() {
        let inst = DsaInstance::from_triples(&[(100, 0, 2), (100, 2, 4), (100, 4, 6)]);
        let sol = solve(&inst);
        assert_eq!(sol.offsets, vec![0, 0, 0]);
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn fills_gaps_left_by_frees() {
        // A[0,6) and B[0,2) stack; after B frees, C(2,[2,6)) fits B's hole.
        let inst = DsaInstance::from_triples(&[(4, 0, 6), (2, 0, 2), (2, 2, 6)]);
        let sol = solve(&inst);
        assert_eq!(sol.offsets[2], 4, "C should reuse B's freed space");
        assert_eq!(sol.peak, 6);
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn valid_on_random_instances() {
        let mut rng = Pcg32::seeded(23);
        for case in 0..20 {
            let triples: Vec<(u64, u64, u64)> = (0..80)
                .map(|_| {
                    let a = rng.range(0, 200);
                    (rng.range(1, 1024), a, a + rng.range(1, 60))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            let sol = solve(&inst);
            sol.validate(&inst)
                .unwrap_or_else(|e| panic!("case {case}: {e}"));
            assert!(sol.peak >= inst.lower_bound());
        }
    }

    #[test]
    fn bestfit_not_worse_on_lifo_pattern() {
        // On the nested (LIFO) pattern typical of DNN propagation the
        // offline best-fit should do at least as well as online first-fit.
        let inst = DsaInstance::from_triples(&[
            (8, 0, 10),
            (4, 1, 9),
            (2, 2, 8),
            (1, 3, 7),
            (6, 4, 6),
        ]);
        let ff = solve(&inst);
        let bf = super::super::bestfit::solve(&inst);
        assert!(bf.peak <= ff.peak);
    }
}
