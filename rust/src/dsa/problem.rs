//! DSA instance model: blocks with fixed lifetimes, colliding pairs, and
//! lower bounds on the achievable peak.

use crate::util::json::Json;

/// One profiled memory block (§3.1 parameters): size `w_i` and lifetime
/// `[alloc_at, free_at)` on the integer profiling clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Block {
    /// Dense id; equals the block's index in [`DsaInstance::blocks`].
    pub id: usize,
    /// Size `w_i` in bytes (already alignment-padded by the profiler).
    pub size: u64,
    /// Request tick `y_i` (inclusive).
    pub alloc_at: u64,
    /// Release tick `ȳ_i` (exclusive). `free_at > alloc_at` always holds.
    pub free_at: u64,
}

impl Block {
    pub fn new(id: usize, size: u64, alloc_at: u64, free_at: u64) -> Block {
        assert!(free_at > alloc_at, "block {id}: empty lifetime");
        assert!(size > 0, "block {id}: zero size");
        Block {
            id,
            size,
            alloc_at,
            free_at,
        }
    }

    /// Lifetime length (the "width" of the rectangle).
    pub fn lifetime(&self) -> u64 {
        self.free_at - self.alloc_at
    }

    /// Do two blocks' lifetimes overlap (half-open interval intersection)?
    pub fn overlaps(&self, other: &Block) -> bool {
        self.alloc_at < other.free_at && other.alloc_at < self.free_at
    }
}

/// A DSA instance: the blocks plus the available device capacity `W`.
#[derive(Debug, Clone, Default)]
pub struct DsaInstance {
    pub blocks: Vec<Block>,
    /// Available maximum memory size `W`; `None` = unbounded (the MIP's
    /// big-M still needs a finite W, for which [`Self::big_m`] is used).
    pub capacity: Option<u64>,
}

impl DsaInstance {
    pub fn new(blocks: Vec<Block>) -> DsaInstance {
        for (i, b) in blocks.iter().enumerate() {
            assert_eq!(b.id, i, "block ids must be dense and ordered");
        }
        DsaInstance {
            blocks,
            capacity: None,
        }
    }

    pub fn with_capacity(mut self, capacity: u64) -> DsaInstance {
        self.capacity = Some(capacity);
        self
    }

    /// Convenience constructor from `(size, alloc_at, free_at)` triples.
    pub fn from_triples(triples: &[(u64, u64, u64)]) -> DsaInstance {
        DsaInstance::new(
            triples
                .iter()
                .enumerate()
                .map(|(i, &(w, a, f))| Block::new(i, w, a, f))
                .collect(),
        )
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// The set `E` of possible colliding pairs (§3.1): pairs with
    /// overlapping lifetimes, `i < j`. Computed with a sweep over
    /// allocation order — O(n log n + |E|) rather than the naive O(n²)
    /// — because Inception-ResNet training traces reach tens of
    /// thousands of blocks.
    pub fn colliding_pairs(&self) -> Vec<(usize, usize)> {
        let mut order: Vec<usize> = (0..self.blocks.len()).collect();
        order.sort_unstable_by_key(|&i| self.blocks[i].alloc_at);
        let mut live: Vec<usize> = Vec::new();
        let mut pairs = Vec::new();
        for &i in &order {
            let b = &self.blocks[i];
            live.retain(|&j| self.blocks[j].free_at > b.alloc_at);
            for &j in &live {
                pairs.push((i.min(j), i.max(j)));
            }
            live.push(i);
        }
        pairs.sort_unstable();
        pairs
    }

    /// The liveness lower bound: the maximum, over time, of the total size
    /// of simultaneously live blocks. No packing can beat this, so it
    /// certifies heuristic quality (§5.2 compares against CPLEX optima;
    /// when the heuristic meets this bound it is provably optimal too).
    pub fn liveness_lower_bound(&self) -> u64 {
        // Event sweep: +size at alloc, -size at free. Frees sort before
        // allocs at the same tick (half-open lifetimes don't collide).
        let mut events: Vec<(u64, i8, u64)> = Vec::with_capacity(self.blocks.len() * 2);
        for b in &self.blocks {
            events.push((b.alloc_at, 1, b.size));
            events.push((b.free_at, 0, b.size));
        }
        events.sort_unstable();
        let (mut cur, mut peak) = (0u64, 0u64);
        for (_, kind, size) in events {
            if kind == 1 {
                cur += size;
                peak = peak.max(cur);
            } else {
                cur -= size;
            }
        }
        peak
    }

    /// Largest single block — a second trivial lower bound.
    pub fn max_block_size(&self) -> u64 {
        self.blocks.iter().map(|b| b.size).max().unwrap_or(0)
    }

    /// Lower bound used for pruning: max of the liveness and single-block
    /// bounds.
    pub fn lower_bound(&self) -> u64 {
        self.liveness_lower_bound().max(self.max_block_size())
    }

    /// Sum of all block sizes — the trivial upper bound (every block gets
    /// its own address space).
    pub fn total_size(&self) -> u64 {
        self.blocks.iter().map(|b| b.size).sum()
    }

    /// Big-M for the MIP formulation: the declared capacity, else the
    /// trivial upper bound.
    pub fn big_m(&self) -> u64 {
        self.capacity.unwrap_or_else(|| self.total_size().max(1))
    }

    /// Clock horizon (one past the last free tick).
    pub fn horizon(&self) -> u64 {
        self.blocks.iter().map(|b| b.free_at).max().unwrap_or(0)
    }

    // ----- JSON (trace files, experiment fixtures) ------------------------

    /// Errors if any size/tick exceeds `i64::MAX`: the JSON integer
    /// domain is i64, and `as i64` would wrap such a value negative.
    pub fn to_json(&self) -> anyhow::Result<Json> {
        let int = |field: &str, v: u64| -> anyhow::Result<Json> {
            let v = i64::try_from(v)
                .map_err(|_| anyhow::anyhow!("{field} {v} exceeds the JSON integer range"))?;
            Ok(Json::Int(v))
        };
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            blocks.push(Json::from_pairs(vec![
                ("size", int("size", b.size)?),
                ("alloc_at", int("alloc_at", b.alloc_at)?),
                ("free_at", int("free_at", b.free_at)?),
            ]));
        }
        let mut obj = Json::obj();
        obj.set("blocks", Json::Arr(blocks));
        if let Some(c) = self.capacity {
            obj.set("capacity", int("capacity", c)?);
        }
        Ok(obj)
    }

    pub fn from_json(j: &Json) -> anyhow::Result<DsaInstance> {
        let arr = j
            .get("blocks")
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("missing blocks array"))?;
        let mut blocks = Vec::with_capacity(arr.len());
        for (i, bj) in arr.iter().enumerate() {
            let size = bj
                .get("size")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("block {i}: bad size"))?;
            let alloc_at = bj
                .get("alloc_at")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("block {i}: bad alloc_at"))?;
            let free_at = bj
                .get("free_at")
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("block {i}: bad free_at"))?;
            anyhow::ensure!(free_at > alloc_at, "block {i}: empty lifetime");
            anyhow::ensure!(size > 0, "block {i}: zero size");
            blocks.push(Block::new(i, size, alloc_at, free_at));
        }
        let mut inst = DsaInstance::new(blocks);
        inst.capacity = match j.get("capacity") {
            Json::Null => None,
            c => Some(
                c.as_u64()
                    .ok_or_else(|| anyhow::anyhow!("negative or non-integer capacity"))?,
            ),
        };
        Ok(inst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst3() -> DsaInstance {
        // ┌────────┐ 0..4 size 10
        //     ┌────────┐ 2..6 size 20
        //            ┌──┐ 5..7 size 5
        DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)])
    }

    #[test]
    fn overlap_semantics_half_open() {
        let a = Block::new(0, 1, 0, 4);
        let b = Block::new(1, 1, 4, 8); // touching endpoints don't overlap
        let c = Block::new(2, 1, 3, 5);
        assert!(!a.overlaps(&b));
        assert!(a.overlaps(&c));
        assert!(c.overlaps(&b));
        assert!(a.overlaps(&a));
    }

    #[test]
    fn colliding_pairs_sweep() {
        assert_eq!(inst3().colliding_pairs(), vec![(0, 1), (1, 2)]);
    }

    #[test]
    fn colliding_pairs_matches_naive_quadratic() {
        // Cross-check the sweep against the O(n²) definition on a
        // deterministic pseudo-random instance.
        let mut rng = crate::util::rng::Pcg32::seeded(99);
        let blocks: Vec<Block> = (0..60)
            .map(|i| {
                let a = rng.range(0, 100);
                Block::new(i, rng.range(1, 50), a, a + rng.range(1, 30))
            })
            .collect();
        let inst = DsaInstance::new(blocks.clone());
        let mut naive = Vec::new();
        for i in 0..blocks.len() {
            for j in i + 1..blocks.len() {
                if blocks[i].overlaps(&blocks[j]) {
                    naive.push((i, j));
                }
            }
        }
        assert_eq!(inst.colliding_pairs(), naive);
    }

    #[test]
    fn liveness_lower_bound_sweep() {
        // Peak is at t in [2,4): blocks 0 and 1 live → 30.
        assert_eq!(inst3().liveness_lower_bound(), 30);
        // Free-then-alloc at the same tick must not double-count.
        let touching = DsaInstance::from_triples(&[(10, 0, 4), (10, 4, 8)]);
        assert_eq!(touching.liveness_lower_bound(), 10);
    }

    #[test]
    fn bounds_ordering() {
        let i = inst3();
        assert!(i.lower_bound() <= i.total_size());
        assert_eq!(i.max_block_size(), 20);
        assert_eq!(i.total_size(), 35);
        assert_eq!(i.horizon(), 7);
    }

    #[test]
    fn json_roundtrip() {
        let i = inst3().with_capacity(1 << 30);
        let j = i.to_json().unwrap();
        let back = DsaInstance::from_json(&j).unwrap();
        assert_eq!(back.blocks, i.blocks);
        assert_eq!(back.capacity, i.capacity);
    }

    #[test]
    fn from_json_rejects_malformed() {
        for src in [
            r#"{}"#,
            r#"{"blocks":[{"size":0,"alloc_at":0,"free_at":1}]}"#,
            r#"{"blocks":[{"size":4,"alloc_at":5,"free_at":5}]}"#,
            r#"{"blocks":[{"size":-4,"alloc_at":0,"free_at":1}]}"#,
            r#"{"blocks":[],"capacity":-1}"#,
        ] {
            let j = Json::parse(src).unwrap();
            assert!(DsaInstance::from_json(&j).is_err(), "src={src}");
        }
    }

    #[test]
    fn to_json_rejects_sizes_beyond_json_int_range() {
        let i = DsaInstance::new(vec![Block::new(0, u64::MAX, 0, 1)]);
        assert!(i.to_json().is_err(), "size above i64::MAX must not wrap");
    }
}
