//! Emitter for the paper's §3.1 MIP formulation in CPLEX LP file format.
//!
//! The testbed has no CPLEX, so PGMO solves exactly with
//! [`dsa::exact`](super::exact); this module exists to (a) document the
//! formulation executably, and (b) let anyone with a MIP solver
//! (CPLEX/Gurobi/CBC all read LP format) verify our exact solver
//! externally. The emitted model is, verbatim from the paper:
//!
//! ```text
//! min  u
//! s.t. x_i + w_i <= u                      for i in B            (2)
//!      x_i + w_i <= x_j + z_ij * W         for (i,j) in E        (3)
//!      x_j + w_j <= x_i + (1 - z_ij) * W   for (i,j) in E        (4)
//!      0 <= u <= W                                               (5)
//!      x_i >= 0                                                  (6)
//!      z_ij in {0, 1}
//! ```

use super::problem::DsaInstance;
use std::fmt::Write as _;

/// Render the instance as an LP-format MIP model string.
pub fn to_lp(inst: &DsaInstance) -> String {
    let big_m = inst.big_m();
    let pairs = inst.colliding_pairs();
    let mut s = String::new();
    let _ = writeln!(s, "\\ DSA MIP (Sekiyama et al. 2018, section 3.1)");
    let _ = writeln!(s, "\\ n={} |E|={} W={}", inst.len(), pairs.len(), big_m);
    let _ = writeln!(s, "Minimize\n obj: u");
    let _ = writeln!(s, "Subject To");
    // (2) peak constraints.
    for b in &inst.blocks {
        let _ = writeln!(s, " peak_{}: x_{} - u <= -{}", b.id, b.id, b.size);
    }
    // (3),(4) non-overlap disjunctions.
    for (i, j) in &pairs {
        let (wi, wj) = (inst.blocks[*i].size, inst.blocks[*j].size);
        let _ = writeln!(
            s,
            " no_{i}_{j}_a: x_{i} - x_{j} - {big_m} z_{i}_{j} <= -{wi}"
        );
        let _ = writeln!(
            s,
            " no_{i}_{j}_b: x_{j} - x_{i} + {big_m} z_{i}_{j} <= {}",
            big_m - wj
        );
    }
    // (5),(6) bounds.
    let _ = writeln!(s, "Bounds");
    let _ = writeln!(s, " 0 <= u <= {big_m}");
    for b in &inst.blocks {
        let _ = writeln!(s, " 0 <= x_{}", b.id);
    }
    let _ = writeln!(s, "Binaries");
    for (i, j) in &pairs {
        let _ = writeln!(s, " z_{i}_{j}");
    }
    let _ = writeln!(s, "End");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inst() -> DsaInstance {
        DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)])
    }

    #[test]
    fn emits_expected_constraint_counts() {
        let lp = to_lp(&inst());
        // 3 peak constraints, 2 colliding pairs × 2 rows.
        assert_eq!(lp.matches("peak_").count(), 3);
        assert_eq!(lp.matches("_a:").count(), 2);
        assert_eq!(lp.matches("_b:").count(), 2);
        assert_eq!(lp.matches("\n z_").count(), 2);
        assert!(lp.trim_end().ends_with("End"));
    }

    #[test]
    fn big_m_uses_capacity_when_given() {
        let lp = to_lp(&inst().with_capacity(1000));
        assert!(lp.contains("W=1000"));
        assert!(lp.contains("0 <= u <= 1000"));
    }

    #[test]
    fn non_colliding_pairs_omitted() {
        // Blocks 0 and 2 never overlap in time → no z_0_2 variable.
        let lp = to_lp(&inst());
        assert!(!lp.contains("z_0_2"));
        assert!(lp.contains("z_0_1"));
        assert!(lp.contains("z_1_2"));
    }
}
