//! Budget-bounded planning via checkpoint/recompute (ROADMAP.md
//! `## Budgeted planning`; Chen et al.'s sublinear-memory training is
//! the motivating trade).
//!
//! When the solved peak of an instance exceeds a configured arena
//! budget, no packing can help past the liveness lower bound — the
//! blocks themselves must change. This pass treats *lifetimes* as
//! decision variables: a dropped block is released right after its
//! producing use (`drop_tick = alloc_at + 1`) and re-materialized just
//! before its next use (`recompute_tick = free_at - 1`), splitting its
//! lifetime into two one-tick segments and freeing `size ×
//! (lifetime - 2)` byte·ticks in between, at the price of re-running
//! its producer once per replayed iteration.
//!
//! The selection is greedy: re-solve, find the first peak-liveness
//! tick, and among the still-unsplit blocks whose freed window covers
//! that tick pick the one with the lowest recompute-cost per freed
//! byte·tick (per-op costs from [`crate::graph::cost`], recorded by the
//! profiler into [`crate::trace::Trace::costs`]). Repeat until the peak
//! fits or no candidate remains — in which case the result is
//! [`BudgetInfeasible`], a hard error, never a silently overshooting
//! plan.

use super::bestfit;
use super::policies::Policy;
use super::problem::{Block, DsaInstance};
use super::solution::Assignment;
use crate::util::json::Json;

/// One drop/recompute decision on an original block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecomputeStep {
    /// Original block id (also the expanded id of its first segment).
    pub id: usize,
    /// Tick at which the checkpointed block is dropped: `alloc_at + 1`.
    pub drop_tick: u64,
    /// Tick at which it is re-materialized: `free_at - 1`.
    pub recompute_tick: u64,
    /// Expanded-instance id of the re-materialized second segment
    /// (`n + k` for the k-th schedule entry over an n-block instance).
    pub segment: usize,
    /// Producer re-run cost in nanoseconds, paid every replay iteration.
    pub cost_ns: u64,
}

impl RecomputeStep {
    pub fn to_json(&self) -> anyhow::Result<Json> {
        let int = |field: &str, v: u64| -> anyhow::Result<Json> {
            let v = i64::try_from(v)
                .map_err(|_| anyhow::anyhow!("{field} {v} exceeds the JSON integer range"))?;
            Ok(Json::Int(v))
        };
        Ok(Json::from_pairs(vec![
            ("id", int("id", self.id as u64)?),
            ("drop_tick", int("drop_tick", self.drop_tick)?),
            ("recompute_tick", int("recompute_tick", self.recompute_tick)?),
            ("segment", int("segment", self.segment as u64)?),
            ("cost_ns", int("cost_ns", self.cost_ns)?),
        ]))
    }

    pub fn from_json(j: &Json) -> anyhow::Result<RecomputeStep> {
        let field = |name: &str| -> anyhow::Result<u64> {
            j.get(name)
                .as_u64()
                .ok_or_else(|| anyhow::anyhow!("recompute step: bad {name}"))
        };
        Ok(RecomputeStep {
            id: field("id")? as usize,
            drop_tick: field("drop_tick")?,
            recompute_tick: field("recompute_tick")?,
            segment: field("segment")? as usize,
            cost_ns: field("cost_ns")?,
        })
    }
}

/// A budget-feasible plan: the expanded instance (split lifetimes plus
/// recompute segments), its packing, and the schedule that produced it.
/// An empty schedule means the unmodified instance already fit.
#[derive(Debug, Clone)]
pub struct BudgetedPlan {
    pub instance: DsaInstance,
    pub assignment: Assignment,
    pub schedule: Vec<RecomputeStep>,
}

/// The budget cannot be met even with every droppable block split.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BudgetInfeasible {
    pub budget: u64,
    /// Best (lowest) peak the pass achieved before giving up.
    pub best_peak: u64,
}

impl std::fmt::Display for BudgetInfeasible {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "arena budget {} infeasible: best achievable peak {} even with \
             every droppable block recomputed",
            self.budget, self.best_peak
        )
    }
}

impl std::error::Error for BudgetInfeasible {}

/// Rebuild the expanded instance an original instance + schedule imply,
/// validating the schedule against the blocks (used when adopting a
/// persisted plan — the disk is never trusted over the invariants).
pub fn expand_instance(
    inst: &DsaInstance,
    schedule: &[RecomputeStep],
) -> anyhow::Result<DsaInstance> {
    let n = inst.len();
    let mut split = vec![false; n];
    let mut blocks = inst.blocks.clone();
    for (k, step) in schedule.iter().enumerate() {
        anyhow::ensure!(step.id < n, "recompute step {k}: id {} out of range", step.id);
        anyhow::ensure!(!split[step.id], "recompute step {k}: block {} split twice", step.id);
        let b = inst.blocks[step.id];
        anyhow::ensure!(
            b.free_at >= b.alloc_at + 3,
            "recompute step {k}: block {} lifetime too short to split",
            step.id
        );
        anyhow::ensure!(
            step.drop_tick == b.alloc_at + 1 && step.recompute_tick == b.free_at - 1,
            "recompute step {k}: ticks disagree with block {} lifetime",
            step.id
        );
        anyhow::ensure!(
            step.segment == n + k,
            "recompute step {k}: segment id {} != {}",
            step.segment,
            n + k
        );
        split[step.id] = true;
        blocks[step.id] = Block::new(step.id, b.size, b.alloc_at, step.drop_tick);
        blocks.push(Block::new(step.segment, b.size, step.recompute_tick, b.free_at));
    }
    let mut expanded = DsaInstance::new(blocks);
    expanded.capacity = inst.capacity;
    Ok(expanded)
}

/// Plan the instance under a hard arena budget. `costs[id]` is block
/// id's producer re-run cost in ns; an empty (or short) slice falls
/// back to the roofline bandwidth model's price for re-materializing
/// the bytes — the same fallback as [`crate::trace::Trace::recompute_cost`].
pub fn plan_with_budget(
    inst: &DsaInstance,
    costs: &[u64],
    budget: u64,
    policy: Policy,
) -> Result<BudgetedPlan, BudgetInfeasible> {
    let n = inst.len();
    let model = crate::graph::cost::ComputeModel::default();
    let cost_of = |id: usize| -> u64 {
        costs
            .get(id)
            .copied()
            .unwrap_or_else(|| model.kernel_ns(0, inst.blocks[id].size))
    };
    // A block larger than the budget can never fit — dropping shrinks
    // lifetimes, never sizes — so fail fast instead of splitting
    // everything first.
    if inst.max_block_size() > budget {
        return Err(BudgetInfeasible {
            budget,
            best_peak: inst.max_block_size(),
        });
    }

    // Drop order; `schedule[k].segment == n + k` by construction.
    let mut schedule: Vec<RecomputeStep> = Vec::new();
    let mut split = vec![false; n];
    loop {
        let expanded = expand_instance(inst, &schedule)
            .expect("internally built schedule must be consistent");
        let sol = bestfit::solve_with(&expanded, policy);
        if sol.peak <= budget {
            return Ok(BudgetedPlan {
                instance: expanded,
                assignment: sol,
                schedule,
            });
        }

        // Target the first tick of maximum liveness in the *expanded*
        // instance — the packing can't beat that bound, so pressure
        // there must be relieved by splitting a block whose freed
        // window `[alloc_at+1, free_at-1)` covers it.
        let t_star = argmax_liveness_tick(&expanded);
        let droppable = |id: usize| -> bool {
            let b = &inst.blocks[id];
            !split[id] && b.free_at >= b.alloc_at + 3
        };
        // Score: recompute cost per freed byte·tick — cheapest trade
        // first; ties break toward the lower id for determinism.
        let score = |id: usize| -> f64 {
            let b = &inst.blocks[id];
            let freed = b.size as f64 * (b.free_at - b.alloc_at - 2) as f64;
            cost_of(id) as f64 / freed
        };
        let pick = |ids: &mut dyn Iterator<Item = usize>| -> Option<usize> {
            ids.min_by(|&a, &b| {
                score(a)
                    .partial_cmp(&score(b))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(&b))
            })
        };
        let at_peak = pick(
            &mut (0..n).filter(|&id| {
                let b = &inst.blocks[id];
                droppable(id) && b.alloc_at < t_star && t_star < b.free_at - 1
            }),
        );
        // No droppable block spans the peak tick (its liveness there is
        // irreducible): fall back to the cheapest remaining candidate
        // anywhere — relieving other ticks can still un-fragment the
        // packing — and fail only when nothing is left to split.
        let chosen = match at_peak.or_else(|| pick(&mut (0..n).filter(|&id| droppable(id)))) {
            Some(id) => id,
            None => {
                return Err(BudgetInfeasible {
                    budget,
                    best_peak: sol.peak,
                })
            }
        };
        let b = inst.blocks[chosen];
        split[chosen] = true;
        schedule.push(RecomputeStep {
            id: chosen,
            drop_tick: b.alloc_at + 1,
            recompute_tick: b.free_at - 1,
            segment: n + schedule.len(),
            cost_ns: cost_of(chosen),
        });
    }
}

/// First tick achieving the maximum total size of simultaneously live
/// blocks (the liveness lower bound's argmax).
fn argmax_liveness_tick(inst: &DsaInstance) -> u64 {
    // Event sweep mirroring `liveness_lower_bound`: frees sort before
    // allocs at the same tick (half-open lifetimes don't collide).
    let mut events: Vec<(u64, i8, u64)> = Vec::with_capacity(inst.blocks.len() * 2);
    for b in &inst.blocks {
        events.push((b.alloc_at, 1, b.size));
        events.push((b.free_at, 0, b.size));
    }
    events.sort_unstable();
    let (mut cur, mut peak, mut at) = (0u64, 0u64, 0u64);
    for (tick, kind, size) in events {
        if kind == 1 {
            cur += size;
            if cur > peak {
                peak = cur;
                at = tick;
            }
        } else {
            cur -= size;
        }
    }
    at
}

/// Total recompute cost of a schedule in nanoseconds — the per-iteration
/// compute overhead a replayed budgeted plan pays.
pub fn schedule_cost_ns(schedule: &[RecomputeStep]) -> u64 {
    schedule.iter().map(|s| s.cost_ns).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roomy_budget_returns_untouched_instance() {
        let inst = DsaInstance::from_triples(&[(100, 0, 10), (100, 4, 6)]);
        let unbudgeted = bestfit::solve_with(&inst, Policy::default());
        let plan = plan_with_budget(&inst, &[], u64::MAX, Policy::default()).unwrap();
        assert!(plan.schedule.is_empty());
        assert_eq!(plan.instance.len(), inst.len());
        assert_eq!(plan.assignment, unbudgeted);
    }

    #[test]
    fn drops_the_spanning_block_to_meet_budget() {
        // A spans the whole horizon; B spikes in the middle. Peak 200.
        // Dropping A leaves one-tick segments at [0,1) and [9,10) that
        // don't overlap B's [4,6): peak falls to 100.
        let inst = DsaInstance::from_triples(&[(100, 0, 10), (100, 4, 6)]);
        let plan = plan_with_budget(&inst, &[], 100, Policy::default()).unwrap();
        assert!(plan.assignment.peak <= 100);
        plan.assignment.validate(&plan.instance).unwrap();
        assert_eq!(plan.schedule.len(), 1);
        let step = plan.schedule[0];
        assert_eq!(step.id, 0);
        assert_eq!(step.drop_tick, 1);
        assert_eq!(step.recompute_tick, 9);
        assert_eq!(step.segment, 2);
        // Expanded instance: A truncated to [0,1), segment at [9,10).
        assert_eq!(plan.instance.blocks[0].free_at, 1);
        assert_eq!(plan.instance.blocks[2].alloc_at, 9);
        assert_eq!(plan.instance.blocks[2].free_at, 10);
    }

    #[test]
    fn picks_the_cheaper_cost_per_freed_byte_tick() {
        // Two identical long blocks; either drop meets the budget. The
        // recorded costs make block 1 the cheaper trade.
        let inst = DsaInstance::from_triples(&[(100, 0, 10), (100, 0, 10), (100, 4, 6)]);
        let plan = plan_with_budget(&inst, &[9_000, 1_000, 1], 200, Policy::default()).unwrap();
        assert!(plan.assignment.peak <= 200);
        assert_eq!(plan.schedule.len(), 1);
        assert_eq!(plan.schedule[0].id, 1, "greedy must take the cheap drop");
        assert_eq!(plan.schedule[0].cost_ns, 1_000);
    }

    #[test]
    fn infeasible_budget_is_a_hard_error() {
        // A single block bigger than the budget can never fit.
        let inst = DsaInstance::from_triples(&[(100, 0, 10)]);
        let err = plan_with_budget(&inst, &[], 50, Policy::default()).unwrap_err();
        assert_eq!(err.budget, 50);
        assert!(err.best_peak > 50);
        assert!(err.to_string().contains("infeasible"));

        // Two blocks overlapping at adjacent ticks: splitting frees
        // nothing (lifetimes of 2 have no gap), so 150 is unreachable.
        let inst = DsaInstance::from_triples(&[(100, 0, 2), (100, 1, 3)]);
        assert!(plan_with_budget(&inst, &[], 150, Policy::default()).is_err());
    }

    #[test]
    fn expand_rejects_inconsistent_schedules() {
        let inst = DsaInstance::from_triples(&[(100, 0, 10), (50, 2, 8)]);
        let good = RecomputeStep {
            id: 0,
            drop_tick: 1,
            recompute_tick: 9,
            segment: 2,
            cost_ns: 7,
        };
        assert!(expand_instance(&inst, &[good]).is_ok());
        for bad in [
            RecomputeStep { id: 5, ..good },
            RecomputeStep { drop_tick: 2, ..good },
            RecomputeStep { recompute_tick: 8, ..good },
            RecomputeStep { segment: 3, ..good },
        ] {
            assert!(expand_instance(&inst, &[bad]).is_err(), "{bad:?}");
        }
        // Splitting the same block twice is rejected.
        let twice = [good, RecomputeStep { segment: 3, ..good }];
        assert!(expand_instance(&inst, &twice).is_err());
    }

    #[test]
    fn step_json_roundtrips() {
        let step = RecomputeStep {
            id: 3,
            drop_tick: 4,
            recompute_tick: 17,
            segment: 12,
            cost_ns: 123_456,
        };
        let back = RecomputeStep::from_json(&step.to_json().unwrap()).unwrap();
        assert_eq!(back, step);
    }

    #[test]
    fn every_policy_meets_the_budget_or_errors() {
        let inst = DsaInstance::from_triples(&[
            (64, 0, 12),
            (32, 1, 11),
            (128, 3, 7),
            (64, 4, 6),
            (16, 8, 10),
        ]);
        for bc in super::super::policies::BlockChoice::ALL {
            let policy = Policy { block_choice: bc };
            let lb = inst.liveness_lower_bound();
            for budget in [lb, lb / 2, 128, 160] {
                match plan_with_budget(&inst, &[], budget, policy) {
                    Ok(plan) => {
                        assert!(plan.assignment.peak <= budget, "{bc:?} budget {budget}");
                        plan.assignment.validate(&plan.instance).unwrap();
                    }
                    Err(e) => assert_eq!(e.budget, budget),
                }
            }
        }
    }
}
