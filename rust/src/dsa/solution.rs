//! Offset assignments produced by the DSA solvers, plus the validator that
//! certifies a packing is collision-free — the safety property the whole
//! optimization rests on.

use super::problem::DsaInstance;

/// A solved packing: `offsets[i]` is `x_i`, `peak = max_i(x_i + w_i)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Assignment {
    pub offsets: Vec<u64>,
    pub peak: u64,
}

/// Violations detected by [`Assignment::validate`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Violation {
    WrongLength { got: usize, want: usize },
    Collision { a: usize, b: usize },
    WrongPeak { declared: u64, actual: u64 },
    OverCapacity { peak: u64, capacity: u64 },
}

impl std::fmt::Display for Violation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            Violation::WrongLength { got, want } => {
                write!(f, "assignment covers {got} blocks, instance has {want}")
            }
            Violation::Collision { a, b } => {
                write!(f, "blocks {a} and {b} overlap in time and address space")
            }
            Violation::WrongPeak { declared, actual } => {
                write!(f, "declared peak {declared} != actual peak {actual}")
            }
            Violation::OverCapacity { peak, capacity } => {
                write!(f, "peak {peak} exceeds capacity {capacity}")
            }
        }
    }
}

impl std::error::Error for Violation {}

impl Assignment {
    /// Build from offsets, computing the peak.
    pub fn from_offsets(inst: &DsaInstance, offsets: Vec<u64>) -> Assignment {
        assert_eq!(offsets.len(), inst.len());
        let peak = inst
            .blocks
            .iter()
            .map(|b| offsets[b.id] + b.size)
            .max()
            .unwrap_or(0);
        Assignment { offsets, peak }
    }

    /// Verify the §3.1 constraints: every colliding pair is disjoint in
    /// address space, the declared peak matches, and capacity (if any)
    /// is respected.
    pub fn validate(&self, inst: &DsaInstance) -> Result<(), Violation> {
        if self.offsets.len() != inst.len() {
            return Err(Violation::WrongLength {
                got: self.offsets.len(),
                want: inst.len(),
            });
        }
        let actual = inst
            .blocks
            .iter()
            .map(|b| self.offsets[b.id] + b.size)
            .max()
            .unwrap_or(0);
        if actual != self.peak {
            return Err(Violation::WrongPeak {
                declared: self.peak,
                actual,
            });
        }
        if let Some(cap) = inst.capacity {
            if self.peak > cap {
                return Err(Violation::OverCapacity {
                    peak: self.peak,
                    capacity: cap,
                });
            }
        }
        for (i, j) in inst.colliding_pairs() {
            let (bi, bj) = (&inst.blocks[i], &inst.blocks[j]);
            let (xi, xj) = (self.offsets[i], self.offsets[j]);
            let disjoint = xi + bi.size <= xj || xj + bj.size <= xi;
            if !disjoint {
                return Err(Violation::Collision { a: i, b: j });
            }
        }
        Ok(())
    }

    /// Relative gap to a lower bound: `(peak - lb) / lb`. Zero means the
    /// solution is provably optimal.
    pub fn gap_to(&self, lower_bound: u64) -> f64 {
        if lower_bound == 0 {
            return 0.0;
        }
        (self.peak as f64 - lower_bound as f64) / lower_bound as f64
    }

    /// Fraction of the trivial no-sharing packing this solution needs —
    /// the headline "memory reduction" number.
    pub fn reduction_vs_total(&self, inst: &DsaInstance) -> f64 {
        let total = inst.total_size();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.peak as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsa::problem::DsaInstance;

    fn inst() -> DsaInstance {
        DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)])
    }

    #[test]
    fn valid_assignment_passes() {
        // 0 at [0,10), 1 at [10,30), 2 at [0,5): 0–1 overlap in time but
        // not space; 1–2 likewise; 0–2 don't overlap in time.
        let a = Assignment::from_offsets(&inst(), vec![0, 10, 0]);
        assert_eq!(a.peak, 30);
        assert!(a.validate(&inst()).is_ok());
    }

    #[test]
    fn collision_detected() {
        let a = Assignment::from_offsets(&inst(), vec![0, 5, 0]);
        assert_eq!(
            a.validate(&inst()),
            Err(Violation::Collision { a: 0, b: 1 })
        );
    }

    #[test]
    fn wrong_peak_detected() {
        let mut a = Assignment::from_offsets(&inst(), vec![0, 10, 0]);
        a.peak = 31;
        assert!(matches!(
            a.validate(&inst()),
            Err(Violation::WrongPeak { .. })
        ));
    }

    #[test]
    fn capacity_enforced() {
        let i = inst().with_capacity(25);
        let a = Assignment::from_offsets(&i, vec![0, 10, 0]);
        assert_eq!(
            a.validate(&i),
            Err(Violation::OverCapacity {
                peak: 30,
                capacity: 25
            })
        );
    }

    #[test]
    fn gap_and_reduction() {
        let a = Assignment::from_offsets(&inst(), vec![0, 10, 0]);
        assert_eq!(a.gap_to(30), 0.0);
        assert!((a.gap_to(20) - 0.5).abs() < 1e-12);
        assert!((a.reduction_vs_total(&inst()) - (1.0 - 30.0 / 35.0)).abs() < 1e-12);
    }

    #[test]
    fn touching_blocks_same_offset_ok() {
        // Blocks that touch in time (half-open) may share the same space.
        let i = DsaInstance::from_triples(&[(10, 0, 4), (10, 4, 8)]);
        let a = Assignment::from_offsets(&i, vec![0, 0]);
        assert!(a.validate(&i).is_ok());
        assert_eq!(a.peak, 10);
    }
}
