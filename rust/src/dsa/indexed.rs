//! Indexed *offset line* structure — the O(log S) skyline behind the
//! fast best-fit solver.
//!
//! [`Skyline`](super::skyline::Skyline) keeps its segments in a `Vec`, so
//! every `lowest_leftmost` is an O(S) scan and every `place`/`lift` pays
//! an O(S) `splice`/`remove` shift. That is fine offline, but since plans
//! build lazily on the serving path (a `PlanRegistry` miss solves inside
//! the request loop), solve latency is now serving latency.
//! [`IndexedSkyline`] stores the same segment list in a slab-backed
//! doubly-linked list — splits and merges relink neighbours instead of
//! shifting elements — and maintains a `BTreeSet<(height, t0, slot)>`
//! min-index whose first entry *is* the lowest-leftmost line:
//!
//! * `lowest_leftmost` — O(log S) (ordered-set minimum);
//! * `place` — O(log S) amortized: ≤2 node insertions, ≤2 merges, ≤5
//!   index updates;
//! * `lift` — O(log S) amortized: one key update, ≤2 merges.
//!
//! Semantics are bit-for-bit those of the reference `Skyline` (§3.2):
//! identical segment lists, identical chosen lines, identical offsets.
//! `rust/tests/properties.rs` drives both in lockstep over the committed
//! fuzz corpus to pin that equivalence.
//!
//! Structural changes (segment splits and merges) are reported through a
//! [`Changes`] log so the solver's
//! [`CandidateIndex`](super::candidates::CandidateIndex) can mirror the
//! window partition without rescanning anything.

use super::skyline::Seg;
use std::collections::BTreeSet;

/// Stable handle to one segment in the slab (reused after frees).
pub type Slot = usize;

/// A time span `[t0, t1)` — a segment's extent without its height.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub t0: u64,
    pub t1: u64,
}

impl Span {
    /// Is lifetime `[alloc_at, free_at)` contained in this span?
    pub fn contains(&self, alloc_at: u64, free_at: u64) -> bool {
        self.t0 <= alloc_at && free_at <= self.t1
    }
}

/// One structural change to the skyline's window partition.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ChangeEvent {
    /// A placement split `parent` into `children[..n]` (in time order).
    Split {
        parent: Span,
        children: [Span; 3],
        n: usize,
    },
    /// Equal-height neighbours merged; the boundary `left.t1 == right.t0`
    /// vanished and the union span survives.
    Merge { left: Span, right: Span },
}

/// Reusable structural-change log: cleared at the start of every
/// `place`/`lift`, holding that one call's events in order afterwards.
#[derive(Debug, Default)]
pub struct Changes {
    pub events: Vec<ChangeEvent>,
}

#[derive(Debug, Clone, Copy)]
struct Node {
    seg: Seg,
    prev: Option<Slot>,
    next: Option<Slot>,
}

/// The indexed skyline: a slab-backed doubly-linked segment list plus an
/// ordered `(height, t0, slot)` min-index.
#[derive(Debug, Clone)]
pub struct IndexedSkyline {
    nodes: Vec<Node>,
    /// Free slab slots, reused by later splits.
    free: Vec<Slot>,
    head: Slot,
    len: usize,
    /// Every live segment under its `(height, t0, slot)` key: the set
    /// minimum is the lowest (leftmost on ties) offset line of §3.2.
    index: BTreeSet<(u64, u64, Slot)>,
}

impl IndexedSkyline {
    /// Fresh skyline at height 0 over `[0, horizon)`.
    pub fn new(horizon: u64) -> IndexedSkyline {
        assert!(horizon > 0, "empty horizon");
        let seg = Seg {
            t0: 0,
            t1: horizon,
            height: 0,
        };
        IndexedSkyline {
            nodes: vec![Node {
                seg,
                prev: None,
                next: None,
            }],
            free: Vec::new(),
            head: 0,
            len: 1,
            index: BTreeSet::from([(0, 0, 0)]),
        }
    }

    /// Seed an indexed skyline from an explicit segment list — the
    /// warm-start re-solve (`bestfit::resolve`) starts from the envelope
    /// of kept placements instead of a flat line. The list must satisfy
    /// the structural invariants: contiguous cover starting at 0,
    /// positive spans, height-distinct neighbours.
    pub fn from_segments(segs: &[Seg]) -> IndexedSkyline {
        assert!(!segs.is_empty(), "empty skyline");
        let mut nodes = Vec::with_capacity(segs.len());
        let mut index = BTreeSet::new();
        let mut t = 0;
        for (i, &seg) in segs.iter().enumerate() {
            assert!(
                seg.t0 == t && seg.t1 > seg.t0,
                "segment {i} breaks the contiguous cover"
            );
            if i > 0 {
                assert_ne!(
                    segs[i - 1].height,
                    seg.height,
                    "equal heights at segments {} and {i}",
                    i - 1
                );
            }
            t = seg.t1;
            nodes.push(Node {
                seg,
                prev: i.checked_sub(1),
                next: if i + 1 < segs.len() { Some(i + 1) } else { None },
            });
            index.insert((seg.height, seg.t0, i));
        }
        IndexedSkyline {
            nodes,
            free: Vec::new(),
            head: 0,
            len: segs.len(),
            index,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0 // never true: the skyline always covers the horizon
    }

    pub fn seg(&self, slot: Slot) -> Seg {
        self.nodes[slot].seg
    }

    /// Slot of the lowest offset line; leftmost wins ties (§3.2).
    /// O(log S): the min-index orders by `(height, t0)`.
    pub fn lowest_leftmost(&self) -> Slot {
        self.index.iter().next().expect("skyline never empty").2
    }

    /// Highest offset line — after all placements this equals the packing
    /// peak.
    pub fn max_height(&self) -> u64 {
        self.index.iter().next_back().expect("skyline never empty").0
    }

    /// The segment list in time order (tests and diagnostics; O(S)).
    pub fn segments(&self) -> Vec<Seg> {
        let mut out = Vec::with_capacity(self.len);
        let mut cur = Some(self.head);
        while let Some(s) = cur {
            out.push(self.nodes[s].seg);
            cur = self.nodes[s].next;
        }
        out
    }

    /// Slot of the segment starting at `t0`, if any (test driver; O(S)).
    pub fn slot_at(&self, t0: u64) -> Option<Slot> {
        let mut cur = Some(self.head);
        while let Some(s) = cur {
            if self.nodes[s].seg.t0 == t0 {
                return Some(s);
            }
            cur = self.nodes[s].next;
        }
        None
    }

    fn alloc_node(&mut self, seg: Seg, prev: Option<Slot>, next: Option<Slot>) -> Slot {
        let node = Node { seg, prev, next };
        let slot = match self.free.pop() {
            Some(s) => {
                self.nodes[s] = node;
                s
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.index.insert((seg.height, seg.t0, slot));
        self.len += 1;
        slot
    }

    /// Drop `slot` from the index and slab. Links must already be rewired
    /// by the caller.
    fn free_node(&mut self, slot: Slot) {
        let seg = self.nodes[slot].seg;
        let removed = self.index.remove(&(seg.height, seg.t0, slot));
        debug_assert!(removed, "freed slot was not indexed");
        self.free.push(slot);
        self.len -= 1;
    }

    /// Rewrite a node's segment, keeping its index key in sync.
    fn set_seg(&mut self, slot: Slot, seg: Seg) {
        let old = self.nodes[slot].seg;
        if (old.height, old.t0) != (seg.height, seg.t0) {
            let removed = self.index.remove(&(old.height, old.t0, slot));
            debug_assert!(removed, "rewritten slot was not indexed");
            self.index.insert((seg.height, seg.t0, slot));
        }
        self.nodes[slot].seg = seg;
    }

    /// Place a block with lifetime `[alloc_at, free_at)` and size `size`
    /// on segment `slot`; returns the assigned offset (the segment
    /// height). The lifetime must be contained in the segment span.
    /// `changes` is cleared and receives this call's split/merge events.
    pub fn place(
        &mut self,
        slot: Slot,
        alloc_at: u64,
        free_at: u64,
        size: u64,
        changes: &mut Changes,
    ) -> u64 {
        changes.events.clear();
        let seg = self.nodes[slot].seg;
        assert!(
            seg.contains(alloc_at, free_at),
            "block [{alloc_at},{free_at}) not contained in segment [{},{})",
            seg.t0,
            seg.t1
        );
        assert!(size > 0);
        let offset = seg.height;

        let mut children = [Span { t0: 0, t1: 0 }; 3];
        let mut n = 0;
        if alloc_at > seg.t0 {
            children[n] = Span {
                t0: seg.t0,
                t1: alloc_at,
            };
            n += 1;
        }
        children[n] = Span {
            t0: alloc_at,
            t1: free_at,
        };
        n += 1;
        if free_at < seg.t1 {
            children[n] = Span {
                t0: free_at,
                t1: seg.t1,
            };
            n += 1;
        }
        if n > 1 {
            changes.events.push(ChangeEvent::Split {
                parent: Span {
                    t0: seg.t0,
                    t1: seg.t1,
                },
                children,
                n,
            });
        }

        // `slot` becomes the raised segment; fresh nodes carry the
        // surviving low spans on either side — no element shifting.
        if alloc_at > seg.t0 {
            let prev = self.nodes[slot].prev;
            let left = self.alloc_node(
                Seg {
                    t0: seg.t0,
                    t1: alloc_at,
                    height: seg.height,
                },
                prev,
                Some(slot),
            );
            match prev {
                Some(p) => self.nodes[p].next = Some(left),
                None => self.head = left,
            }
            self.nodes[slot].prev = Some(left);
        }
        if free_at < seg.t1 {
            let next = self.nodes[slot].next;
            let right = self.alloc_node(
                Seg {
                    t0: free_at,
                    t1: seg.t1,
                    height: seg.height,
                },
                Some(slot),
                next,
            );
            if let Some(nx) = next {
                self.nodes[nx].prev = Some(right);
            }
            self.nodes[slot].next = Some(right);
        }
        self.set_seg(
            slot,
            Seg {
                t0: alloc_at,
                t1: free_at,
                height: seg.height + size,
            },
        );

        // Equal-height neighbours are only possible against the raised
        // segment itself: the split's low children keep the parent
        // height, which differed from the old neighbours' by invariant.
        let survivor = self.merge_if_equal_left(slot, changes);
        self.merge_if_equal_right(survivor, changes);
        offset
    }

    /// Lift the offset line `slot` into its lowest adjacent neighbour
    /// (both, when they tie) — the §3.2 move used when no unplaced block
    /// fits the chosen line. Panics on a single-segment skyline (the
    /// caller's search must have found a block in that case). `changes`
    /// is cleared and receives this call's merge events.
    pub fn lift(&mut self, slot: Slot, changes: &mut Changes) {
        changes.events.clear();
        let (prev, next) = (self.nodes[slot].prev, self.nodes[slot].next);
        let left = prev.map(|p| self.nodes[p].seg.height);
        let right = next.map(|n| self.nodes[n].seg.height);
        let target = match (left, right) {
            (Some(l), Some(r)) => l.min(r),
            (Some(l), None) => l,
            (None, Some(r)) => r,
            (None, None) => panic!("lift on a single-segment skyline"),
        };
        let mut seg = self.nodes[slot].seg;
        debug_assert!(target > seg.height, "lift must raise");
        seg.height = target;
        self.set_seg(slot, seg);
        let survivor = self.merge_if_equal_left(slot, changes);
        self.merge_if_equal_right(survivor, changes);
    }

    /// Merge `slot` into its prev when heights tie; returns the survivor.
    fn merge_if_equal_left(&mut self, slot: Slot, changes: &mut Changes) -> Slot {
        match self.nodes[slot].prev {
            Some(prev) if self.nodes[prev].seg.height == self.nodes[slot].seg.height => {
                self.merge_pair(prev, slot, changes);
                prev
            }
            _ => slot,
        }
    }

    fn merge_if_equal_right(&mut self, slot: Slot, changes: &mut Changes) {
        if let Some(next) = self.nodes[slot].next {
            if self.nodes[next].seg.height == self.nodes[slot].seg.height {
                self.merge_pair(slot, next, changes);
            }
        }
    }

    /// Merge adjacent equal-height `left` and `right`; `left` survives
    /// with the union span. O(log S): `t1` is not part of the index key,
    /// so only `right`'s entry is touched.
    fn merge_pair(&mut self, left: Slot, right: Slot, changes: &mut Changes) {
        let (l, r) = (self.nodes[left].seg, self.nodes[right].seg);
        debug_assert_eq!(l.t1, r.t0, "merge of non-adjacent segments");
        debug_assert_eq!(l.height, r.height, "merge of unequal heights");
        changes.events.push(ChangeEvent::Merge {
            left: Span { t0: l.t0, t1: l.t1 },
            right: Span { t0: r.t0, t1: r.t1 },
        });
        let after = self.nodes[right].next;
        self.free_node(right);
        self.nodes[left].next = after;
        if let Some(a) = after {
            self.nodes[a].prev = Some(left);
        }
        self.nodes[left].seg.t1 = r.t1;
    }

    /// Check structural invariants (tests and debug assertions):
    /// contiguous cover, positive spans, height-distinct neighbours,
    /// coherent links, and an index entry per live segment.
    pub fn check_invariants(&self) -> Result<(), String> {
        if self.len == 0 {
            return Err("empty skyline".into());
        }
        if self.index.len() != self.len {
            return Err(format!(
                "index holds {} entries for {} segments",
                self.index.len(),
                self.len
            ));
        }
        let mut count = 0;
        let mut prev: Option<Slot> = None;
        let mut cur = Some(self.head);
        while let Some(s) = cur {
            let node = &self.nodes[s];
            if node.prev != prev {
                return Err(format!("bad prev link at slot {s}"));
            }
            if node.seg.t1 <= node.seg.t0 {
                return Err(format!("segment at slot {s} has empty span"));
            }
            if let Some(p) = prev {
                let ps = self.nodes[p].seg;
                if ps.t1 != node.seg.t0 {
                    return Err(format!("gap before slot {s}"));
                }
                if ps.height == node.seg.height {
                    return Err(format!("equal heights at slots {p} and {s}"));
                }
            }
            if !self.index.contains(&(node.seg.height, node.seg.t0, s)) {
                return Err(format!("slot {s} missing from the height index"));
            }
            count += 1;
            if count > self.len {
                return Err("cycle in segment list".into());
            }
            prev = cur;
            cur = node.next;
        }
        if count != self.len {
            return Err(format!("list holds {count} segments, len says {}", self.len));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seg(t0: u64, t1: u64, height: u64) -> Seg {
        Seg { t0, t1, height }
    }

    #[test]
    fn place_splits_and_returns_offset() {
        let mut sky = IndexedSkyline::new(10);
        let mut ch = Changes::default();
        let off = sky.place(sky.lowest_leftmost(), 2, 6, 5, &mut ch);
        assert_eq!(off, 0);
        assert_eq!(
            sky.segments(),
            vec![seg(0, 2, 0), seg(2, 6, 5), seg(6, 10, 0)]
        );
        sky.check_invariants().unwrap();
        // One split into three children, no merges.
        assert_eq!(ch.events.len(), 1);
        match ch.events[0] {
            ChangeEvent::Split { parent, children, n } => {
                assert_eq!(parent, Span { t0: 0, t1: 10 });
                assert_eq!(n, 3);
                assert_eq!(children[0], Span { t0: 0, t1: 2 });
                assert_eq!(children[1], Span { t0: 2, t1: 6 });
                assert_eq!(children[2], Span { t0: 6, t1: 10 });
            }
            _ => panic!("expected a split"),
        }
    }

    #[test]
    fn place_full_span_no_split_no_events() {
        let mut sky = IndexedSkyline::new(10);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 10, 3, &mut ch);
        assert_eq!(sky.len(), 1);
        assert_eq!(sky.max_height(), 3);
        assert!(ch.events.is_empty(), "pure raise has no structural change");
    }

    #[test]
    fn equal_height_neighbours_merge_after_place() {
        let mut sky = IndexedSkyline::new(10);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 5, 4, &mut ch); // [0,5)@4 [5,10)@0
        let low = sky.lowest_leftmost();
        assert_eq!(sky.seg(low).t0, 5);
        sky.place(low, 5, 10, 4, &mut ch); // both now height 4 → one segment
        assert_eq!(sky.segments(), vec![seg(0, 10, 4)]);
        sky.check_invariants().unwrap();
        // The raise emitted no split (full sub-span) but one merge.
        assert_eq!(
            ch.events,
            vec![ChangeEvent::Merge {
                left: Span { t0: 0, t1: 5 },
                right: Span { t0: 5, t1: 10 },
            }]
        );
    }

    #[test]
    fn lowest_leftmost_prefers_left_on_ties() {
        let mut sky = IndexedSkyline::new(12);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 4, 8, 2, &mut ch); // [0,4)@0 [4,8)@2 [8,12)@0
        assert_eq!(sky.seg(sky.lowest_leftmost()).t0, 0);
    }

    #[test]
    fn lift_merges_into_lowest_neighbour() {
        let mut sky = IndexedSkyline::new(12);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 4, 7, &mut ch); // [0,4)@7 [4,12)@0
        let low = sky.lowest_leftmost();
        sky.place(low, 8, 12, 3, &mut ch); // [0,4)@7 [4,8)@0 [8,12)@3
        let low = sky.lowest_leftmost();
        assert_eq!(sky.seg(low).height, 0);
        sky.lift(low, &mut ch); // raises [4,8) to min(7,3)=3, merges right
        sky.check_invariants().unwrap();
        assert_eq!(sky.segments(), vec![seg(0, 4, 7), seg(4, 12, 3)]);
        assert_eq!(
            ch.events,
            vec![ChangeEvent::Merge {
                left: Span { t0: 4, t1: 8 },
                right: Span { t0: 8, t1: 12 },
            }]
        );
    }

    #[test]
    fn lift_merges_both_when_neighbours_tie() {
        let mut sky = IndexedSkyline::new(12);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 4, 5, &mut ch);
        sky.place(sky.lowest_leftmost(), 8, 12, 5, &mut ch);
        // [0,4)@5 [4,8)@0 [8,12)@5
        sky.lift(sky.lowest_leftmost(), &mut ch);
        assert_eq!(sky.segments(), vec![seg(0, 12, 5)]);
        assert_eq!(ch.events.len(), 2, "left merge then right merge");
    }

    #[test]
    #[should_panic(expected = "not contained")]
    fn place_outside_span_panics() {
        let mut sky = IndexedSkyline::new(10);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 5, 1, &mut ch); // [0,5)@1 [5,10)@0
        let low = sky.lowest_leftmost();
        sky.place(low, 4, 6, 1, &mut ch); // spans into raised segment
    }

    #[test]
    fn stacking_on_raised_segment() {
        let mut sky = IndexedSkyline::new(8);
        let mut ch = Changes::default();
        sky.place(sky.lowest_leftmost(), 0, 8, 4, &mut ch);
        let top = sky.slot_at(0).unwrap();
        let off = sky.place(top, 2, 6, 3, &mut ch);
        assert_eq!(off, 4);
        assert_eq!(sky.max_height(), 7);
        sky.check_invariants().unwrap();
    }

    #[test]
    fn from_segments_matches_reference_behaviour() {
        let segs = vec![seg(0, 4, 7), seg(4, 9, 0), seg(9, 12, 3)];
        let mut indexed = IndexedSkyline::from_segments(&segs);
        indexed.check_invariants().unwrap();
        assert_eq!(indexed.segments(), segs);
        let mut ch = Changes::default();
        let low = indexed.lowest_leftmost();
        assert_eq!(indexed.seg(low).t0, 4);
        let off = indexed.place(low, 4, 9, 3, &mut ch);
        assert_eq!(off, 0, "seeded height is the placement offset");
        assert_eq!(indexed.segments(), vec![seg(0, 4, 7), seg(4, 12, 3)]);
        indexed.check_invariants().unwrap();
    }

    #[test]
    #[should_panic(expected = "contiguous cover")]
    fn from_segments_rejects_gaps() {
        let _ = IndexedSkyline::from_segments(&[seg(0, 4, 7), seg(5, 9, 0)]);
    }

    #[test]
    fn slots_are_reused_after_merges() {
        let mut sky = IndexedSkyline::new(100);
        let mut ch = Changes::default();
        // Repeated split-then-merge churn must not grow the slab without
        // bound: place a block in the middle, lift the cheap left valley
        // back up until the skyline flattens, repeat.
        for round in 0..20u64 {
            let h = round + 1;
            let low = sky.lowest_leftmost();
            let s = sky.seg(low);
            let mid0 = (s.t0 + s.t1) / 2;
            if mid0 + 1 < s.t1 {
                sky.place(low, mid0, mid0 + 1, h, &mut ch);
            } else {
                sky.place(low, s.t0, s.t1, h, &mut ch);
            }
            while sky.len() > 1 {
                sky.lift(sky.lowest_leftmost(), &mut ch);
            }
            sky.check_invariants().unwrap();
        }
        assert!(
            sky.nodes.len() <= 4,
            "slab grew to {} nodes despite free-list reuse",
            sky.nodes.len()
        );
    }
}
