//! Dynamic Storage Allocation (DSA) — the optimization core of the paper
//! (§3). A profiled propagation yields a set of memory blocks, each with a
//! fixed *lifetime* (allocation/release clock ticks) and size; DSA assigns
//! each block a fixed *offset* in one arena such that blocks whose lifetimes
//! overlap never overlap in address space, minimizing the arena peak.
//!
//! DSA is a special case of two-dimensional strip packing (2SP) where the
//! x-extent (lifetime) of every rectangle is fixed; it is NP-hard
//! [Garey & Johnson 1979]. This module provides:
//!
//! * [`problem`] — instance model, colliding pairs, lower bounds;
//! * [`solution`] — offset assignments and the overlap validator;
//! * [`skyline`] — the *offset line* structure of §3.2;
//! * [`bestfit`] — the paper's best-fit heuristic (after Burke et al. 2004);
//! * [`policies`] — ablatable block-/offset-choice policies;
//! * [`firstfit`] — address-ordered first-fit baseline (what an idealized
//!   online allocator achieves);
//! * [`exact`] — branch-and-bound exact solver standing in for CPLEX;
//! * [`mip`] — LP-format emitter of the paper's §3.1 MIP formulation.

pub mod bestfit;
pub mod exact;
pub mod firstfit;
pub mod mip;
pub mod policies;
pub mod problem;
pub mod skyline;
pub mod solution;

pub use bestfit::solve as solve_bestfit;
pub use problem::{Block, DsaInstance};
pub use solution::{Assignment, Violation};
