//! Dynamic Storage Allocation (DSA) — the optimization core of the paper
//! (§3). A profiled propagation yields a set of memory blocks, each with a
//! fixed *lifetime* (allocation/release clock ticks) and size; DSA assigns
//! each block a fixed *offset* in one arena such that blocks whose lifetimes
//! overlap never overlap in address space, minimizing the arena peak.
//!
//! DSA is a special case of two-dimensional strip packing (2SP) where the
//! x-extent (lifetime) of every rectangle is fixed; it is NP-hard
//! [Garey & Johnson 1979]. This module provides:
//!
//! * [`problem`] — instance model, colliding pairs, lower bounds;
//! * [`solution`] — offset assignments and the overlap validator;
//! * [`skyline`] — the reference *offset line* structure of §3.2;
//! * [`indexed`] — the same structure over a slab-backed linked list with
//!   an ordered height index: O(log S) `lowest_leftmost`/`place`/`lift`;
//! * [`candidates`] — per-window unplaced-block sets ordered by the
//!   active policy key, so each solve step touches only live candidates;
//! * [`bestfit`] — the paper's best-fit heuristic (after Burke et al.
//!   2004): [`bestfit::solve`] runs on the indexed structures (fast
//!   enough for lazy plan builds on the serving path),
//!   [`bestfit::solve_reference`] keeps the original quadratic form for
//!   differential testing, and [`bestfit::resolve`] warm-starts a §4.3
//!   re-solve from the previous assignment plus a
//!   [`bestfit::TraceDelta`], re-placing only the disturbed blocks
//!   (ROADMAP.md `## Incremental re-solve`);
//! * [`policies`] — ablatable block-/offset-choice policies;
//! * [`firstfit`] — address-ordered first-fit baseline (what an idealized
//!   online allocator achieves);
//! * [`exact`] — branch-and-bound exact solver standing in for CPLEX,
//!   with a bounded [`exact::dive`] entry reused by the anytime search;
//! * [`recompute`] — budget-bounded planning: when the solved peak
//!   exceeds a hard arena budget, greedily split block lifetimes into
//!   checkpoint/recompute segments (cheapest recompute-cost per freed
//!   byte·tick first) and re-solve until the peak fits, or fail with
//!   [`recompute::BudgetInfeasible`] — never a silent overshoot
//!   (ROADMAP.md `## Budgeted planning`);
//! * [`anytime`] — anytime improvement of an incumbent packing: policy
//!   restarts, lift-and-replace local moves, and bounded exact dives
//!   under a time slice, with a monotone-incumbent guarantee (the
//!   background re-pack path runs it — ROADMAP.md `## Anytime
//!   improvement`);
//! * [`mip`] — LP-format emitter of the paper's §3.1 MIP formulation.

pub mod anytime;
pub mod bestfit;
pub mod candidates;
pub mod exact;
pub mod firstfit;
pub mod indexed;
pub mod mip;
pub mod policies;
pub mod problem;
pub mod recompute;
pub mod skyline;
pub mod solution;

pub use bestfit::{resolve, solve as solve_bestfit, solve_reference, Resolution, TraceDelta};
pub use problem::{Block, DsaInstance};
pub use solution::{Assignment, Violation};
