//! The paper's best-fit heuristic for DSA (§3.2, after Burke et al. 2004).
//!
//! Repeat until every block is placed:
//!
//! 1. choose the lowest (leftmost on ties) offset line of the skyline;
//! 2. among unplaced blocks whose lifetime fits the line's span, place the
//!    one with the longest lifetime at that offset;
//! 3. if no block fits, *lift* the line into its lowest adjacent line.
//!
//! [`solve`]/[`solve_with`] run the indexed hot path: an
//! [`IndexedSkyline`] makes step 1 an O(log S) ordered-set minimum and
//! the splits/merges of steps 2–3 O(log S) amortized, while a
//! [`CandidateIndex`] keeps the per-window unplaced blocks ordered by the
//! policy key so step 2 is one set lookup instead of a rescan of every
//! block in the window. Plans that build lazily on the serving path (a
//! `PlanRegistry` miss solves inside the request loop) ride this path.
//!
//! [`solve_reference`]/[`solve_reference_with`] keep the original
//! quadratic formulation — an O(S) segment scan per step over the `Vec`
//! skyline, and a candidate loop that rescans already-placed blocks in
//! its alloc-tick window. The two are semantically identical by
//! construction (same chosen line, same chosen block, same offsets, byte
//! for byte); `rust/tests/properties.rs` pins the equivalence across all
//! policies, and `benches/bench_solver_scale.rs` pins the speedup
//! (targets in ROADMAP.md `## Perf targets`).
//!
//! [`resolve`]/[`resolve_with`] warm-start the solver for §4.3
//! reoptimization: given the previous instance, its assignment, and a
//! [`TraceDelta`], they keep every placement the delta does not disturb,
//! seed the skyline from the kept placements' envelope, and re-run the
//! best-fit loop over the disturbed blocks only.
//! [`resolve_reference_with`] is the quadratic spec of the same
//! operation, driven in lockstep by the reopt differential suite
//! (ROADMAP.md `## Incremental re-solve`).
//!
//! [`seed_scaled`]/[`seed_scaled_with`] transfer a solved plan *across
//! batch buckets* (ROADMAP.md `## Plan transfer & re-pack`): a registry
//! miss for bucket `2B` scales bucket `B`'s solved instance along the
//! batch dimension and solves the scaled instance warm instead of
//! profiling from nothing. A uniform integer size ratio takes an exact
//! O(n) offset transfer — the heuristic is scale-equivariant, so
//! multiplying every size and offset by the ratio reproduces what a cold
//! solve of the scaled instance would pack — while fractional ratios run
//! the [`resolve`] warm path over the positional delta, and a skeleton
//! mismatch falls back to a cold solve (the registry's structural
//! fallback rule). [`seed_scaled_reference_with`] is the quadratic spec,
//! driven in lockstep by the seeded-build differential suite.

use super::candidates::CandidateIndex;
use super::indexed::{Changes, IndexedSkyline};
use super::policies::Policy;
use super::problem::DsaInstance;
use super::skyline::{Seg, Skyline};
use super::solution::Assignment;
use std::collections::BTreeMap;

/// Solve with the paper's default policy (longest lifetime).
pub fn solve(inst: &DsaInstance) -> Assignment {
    solve_with(inst, Policy::default())
}

/// Solve with an explicit block-choice policy (ablations), on the
/// indexed hot path.
pub fn solve_with(inst: &DsaInstance, policy: Policy) -> Assignment {
    if inst.is_empty() {
        return Assignment {
            offsets: Vec::new(),
            peak: 0,
        };
    }

    let n = inst.len();
    let mut offsets = vec![0u64; n];
    let mut remaining = n;
    let mut sky = IndexedSkyline::new(inst.horizon());
    let mut cands = CandidateIndex::new(inst, policy);
    let mut changes = Changes::default();

    while remaining > 0 {
        let slot = sky.lowest_leftmost();
        let seg = sky.seg(slot);
        // The window's candidate set mirrors the segment exactly, so the
        // policy winner is one ordered-set lookup.
        match cands.best(seg.t0) {
            Some(bid) => {
                let b = inst.blocks[bid];
                cands.place(bid);
                offsets[bid] = sky.place(slot, b.alloc_at, b.free_at, b.size, &mut changes);
                remaining -= 1;
            }
            // No unplaced block fits the line: lift it (§3.2). A
            // single-segment skyline always has candidates — every
            // lifetime is contained in the full horizon — so lift never
            // sees one.
            None => sky.lift(slot, &mut changes),
        }
        cands.apply(&changes);
    }

    debug_assert!(sky.check_invariants().is_ok());
    Assignment::from_offsets(inst, offsets)
}

/// Reference solver: the paper's default policy on the original
/// quadratic formulation. Kept verbatim for differential testing of the
/// indexed path and as the readable spec of §3.2.
pub fn solve_reference(inst: &DsaInstance) -> Assignment {
    solve_reference_with(inst, Policy::default())
}

/// Reference solver with an explicit block-choice policy.
pub fn solve_reference_with(inst: &DsaInstance, policy: Policy) -> Assignment {
    if inst.is_empty() {
        return Assignment {
            offsets: Vec::new(),
            peak: 0,
        };
    }

    let n = inst.len();
    let mut offsets = vec![0u64; n];
    let mut placed = vec![false; n];
    let mut remaining = n;

    // Blocks sorted by alloc tick: a segment [t0, t1) can only host blocks
    // with alloc_at in [t0, t1), so each candidate scan touches just that
    // window instead of all n blocks.
    let mut by_alloc: Vec<usize> = (0..n).collect();
    by_alloc.sort_unstable_by_key(|&i| inst.blocks[i].alloc_at);
    let alloc_keys: Vec<u64> = by_alloc.iter().map(|&i| inst.blocks[i].alloc_at).collect();

    let mut sky = Skyline::new(inst.horizon());

    while remaining > 0 {
        let idx = sky.lowest_leftmost();
        let seg = sky.seg(idx);

        // Scan candidates with alloc_at ∈ [seg.t0, seg.t1).
        let lo = alloc_keys.partition_point(|&a| a < seg.t0);
        let hi = alloc_keys.partition_point(|&a| a < seg.t1);
        let mut best: Option<usize> = None;
        for &bid in &by_alloc[lo..hi] {
            if placed[bid] {
                continue;
            }
            let b = &inst.blocks[bid];
            if b.free_at > seg.t1 {
                continue; // lifetime exits the span
            }
            match best {
                None => best = Some(bid),
                Some(cur) => {
                    if policy.block_choice.prefer(b, &inst.blocks[cur]) {
                        best = Some(bid);
                    }
                }
            }
        }

        match best {
            Some(bid) => {
                let b = inst.blocks[bid];
                offsets[bid] = sky.place(idx, b.alloc_at, b.free_at, b.size);
                placed[bid] = true;
                remaining -= 1;
            }
            None => sky.lift(idx),
        }
    }

    debug_assert!(sky.check_invariants().is_ok());
    Assignment::from_offsets(inst, offsets)
}

// ----- §4.3 warm-start incremental re-solve ----------------------------------

/// Envelope height marking time regions no disturbed block can occupy.
/// Far above any real packing height (peaks are bounded by the total
/// block size), so such a segment is never the chosen line while real
/// candidates remain, and a lift into one only retires a window that
/// could host nothing anyway.
const DEAD_ZONE: u64 = u64::MAX >> 2;

/// How one block of a re-profiled instance relates to the previously
/// solved instance (ids are positional — the profiler's λ).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlockDelta {
    /// Same size and lifetime as previous block `prev`.
    Unchanged { prev: usize },
    /// Same lifetime as previous block `prev`, different size (the §4.3
    /// size ratchet).
    Resized { prev: usize },
    /// Lifetime changed (a shifted propagation step).
    Moved { prev: usize },
    /// No previous counterpart.
    Added,
}

/// The delta between a previously solved instance and a re-profiled one
/// — what [`resolve`] re-solves instead of the whole trace.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceDelta {
    /// Per new-instance block (index = new id).
    pub blocks: Vec<BlockDelta>,
    /// Previous ids with no surviving counterpart.
    pub removed: Vec<usize>,
}

impl TraceDelta {
    /// Positional diff (replay identifies blocks by position, §4.2):
    /// shared positions compare lifetime then size, surplus new positions
    /// are additions, surplus previous positions removals.
    pub fn diff(prev: &DsaInstance, new: &DsaInstance) -> TraceDelta {
        let shared = prev.len().min(new.len());
        let mut blocks = Vec::with_capacity(new.len());
        for i in 0..shared {
            let (p, n) = (&prev.blocks[i], &new.blocks[i]);
            blocks.push(if (p.alloc_at, p.free_at) != (n.alloc_at, n.free_at) {
                BlockDelta::Moved { prev: i }
            } else if p.size != n.size {
                BlockDelta::Resized { prev: i }
            } else {
                BlockDelta::Unchanged { prev: i }
            });
        }
        blocks.extend((shared..new.len()).map(|_| BlockDelta::Added));
        TraceDelta {
            blocks,
            removed: (shared..prev.len()).collect(),
        }
    }

    /// Number of blocks the delta touches (changed + added + removed).
    pub fn changed(&self) -> usize {
        self.removed.len()
            + self
                .blocks
                .iter()
                .filter(|d| !matches!(d, BlockDelta::Unchanged { .. }))
                .count()
    }

    /// A pure size ratchet: the event skeleton is unchanged and sizes
    /// only grew — the §4.3 reopt that leaves almost every placement
    /// valid, and the case the engine warm-starts.
    pub fn is_ratchet_only(&self, prev: &DsaInstance, new: &DsaInstance) -> bool {
        self.removed.is_empty()
            && self.blocks.iter().enumerate().all(|(id, d)| match *d {
                BlockDelta::Unchanged { .. } => true,
                BlockDelta::Resized { prev: p } => new.blocks[id].size >= prev.blocks[p].size,
                BlockDelta::Moved { .. } | BlockDelta::Added => false,
            })
    }
}

/// Result of a warm-start [`resolve`]: the assignment plus how it was
/// produced.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Resolution {
    pub assignment: Assignment,
    /// Placements re-solved: the delta's blocks plus the transitive
    /// closure of placements stacked above them. Equals the instance
    /// size after a fallback.
    pub disturbed: usize,
    /// False when the incremental path paid a full solve (the warm
    /// packing regressed past the quality gate).
    pub warm: bool,
}

/// The keep/disturb split of a warm re-solve: kept offsets (and their
/// placements' `(alloc_at, free_at, top)` rectangles), plus the new ids
/// to re-place.
struct WarmSplit {
    offsets: Vec<u64>,
    disturbed: Vec<usize>,
    kept: Vec<(u64, u64, u64)>,
}

/// Lifetime-overlap adjacency of the previous instance (which pairs may
/// ever touch in address space), in CSR form — two flat arrays, no
/// per-node allocations, so building it stays a small fraction of a
/// full solve even at 100k blocks.
struct Adjacency {
    start: Vec<usize>,
    flat: Vec<usize>,
}

impl Adjacency {
    fn neighbours(&self, i: usize) -> &[usize] {
        &self.flat[self.start[i]..self.start[i + 1]]
    }
}

fn overlap_adjacency(prev_inst: &DsaInstance) -> Adjacency {
    let n = prev_inst.len();
    let pairs = prev_inst.colliding_pairs();
    let mut start = vec![0usize; n + 1];
    for &(i, j) in &pairs {
        start[i + 1] += 1;
        start[j + 1] += 1;
    }
    for k in 0..n {
        start[k + 1] += start[k];
    }
    let mut cursor = start.clone();
    let mut flat = vec![0usize; pairs.len() * 2];
    for &(i, j) in &pairs {
        flat[cursor[i]] = j;
        cursor[i] += 1;
        flat[cursor[j]] = i;
        cursor[j] += 1;
    }
    Adjacency { start, flat }
}

/// Disturb every previous placement stacked (directly or transitively)
/// above a delta-touched one, so the re-solve can compact the freed or
/// grown region instead of piling new placements on top of stale ones.
fn close_upward(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    adj: &Adjacency,
    disturbed: &mut [bool],
) {
    let mut queue: Vec<usize> = (0..prev_inst.len()).filter(|&i| disturbed[i]).collect();
    while let Some(i) = queue.pop() {
        let top = prev.offsets[i] + prev_inst.blocks[i].size;
        for &j in adj.neighbours(i) {
            if !disturbed[j] && prev.offsets[j] >= top {
                disturbed[j] = true;
                queue.push(j);
            }
        }
    }
}

/// The seeded skyline of a warm re-solve: inside the union of disturbed
/// lifetimes, the upper envelope of kept placements (their tops); outside
/// it, the [`DEAD_ZONE`] line, so the solver neither walks nor wastes
/// space on regions where nothing can be placed.
fn kept_envelope(
    new_inst: &DsaInstance,
    kept: &[(u64, u64, u64)], // (alloc_at, free_at, top) of kept placements
    disturbed: &[usize],
) -> Vec<Seg> {
    let horizon = new_inst.horizon().max(1);
    // Merge disturbed lifetimes into disjoint domain intervals.
    let mut domain: Vec<(u64, u64)> = disturbed
        .iter()
        .map(|&id| (new_inst.blocks[id].alloc_at, new_inst.blocks[id].free_at))
        .collect();
    domain.sort_unstable();
    let mut merged: Vec<(u64, u64)> = Vec::with_capacity(domain.len());
    for (a, f) in domain {
        if let Some(last) = merged.last_mut() {
            if a <= last.1 {
                last.1 = last.1.max(f);
                continue;
            }
        }
        merged.push((a, f));
    }

    // Height-change events of kept placements intersecting the domain
    // (+top at alloc, −top at free; frees sort first at equal ticks since
    // half-open lifetimes do not collide).
    let mut events: Vec<(u64, bool, u64)> = Vec::new();
    for &(a, f, top) in kept {
        let i = merged.partition_point(|&(_, e)| e <= a);
        if merged.get(i).is_some_and(|&(s, _)| s < f) {
            events.push((a, true, top));
            events.push((f, false, top));
        }
    }
    events.sort_unstable();

    // Sweep a multiset of live kept tops across every interesting tick,
    // overriding regions outside the domain with the dead-zone height and
    // merging equal-height neighbours.
    let mut ticks: Vec<u64> = events.iter().map(|&(t, _, _)| t).collect();
    for &(s, e) in &merged {
        ticks.push(s);
        ticks.push(e);
    }
    ticks.push(0);
    ticks.push(horizon);
    ticks.sort_unstable();
    ticks.dedup();

    let mut live: BTreeMap<u64, u32> = BTreeMap::new();
    let mut segs: Vec<Seg> = Vec::new();
    let (mut ev, mut dom) = (0usize, 0usize);
    for w in ticks.windows(2) {
        let (t0, t1) = (w[0], w[1]);
        while ev < events.len() && events[ev].0 <= t0 {
            let (_, is_alloc, top) = events[ev];
            if is_alloc {
                *live.entry(top).or_insert(0) += 1;
            } else {
                match live.get_mut(&top) {
                    Some(c) if *c > 1 => *c -= 1,
                    _ => {
                        live.remove(&top);
                    }
                }
            }
            ev += 1;
        }
        while dom < merged.len() && merged[dom].1 <= t0 {
            dom += 1;
        }
        let inside = merged.get(dom).is_some_and(|&(s, _)| s <= t0);
        let height = if inside {
            live.keys().next_back().copied().unwrap_or(0)
        } else {
            DEAD_ZONE
        };
        if let Some(last) = segs.last_mut() {
            if last.height == height {
                last.t1 = t1;
                continue;
            }
        }
        segs.push(Seg { t0, t1, height });
    }
    segs
}

/// Split the new instance into kept placements (offsets reused from the
/// previous assignment) and disturbed blocks. A resized block whose
/// growth fits the slack above its old placement — no time-overlapping
/// neighbour starts inside the grown band — is an *in-place ratchet*: it
/// keeps its offset (at the new size) and disturbs nothing, which is the
/// §4.3 common case. Shrinks always fit in place.
fn warm_split(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    new_inst: &DsaInstance,
    delta: &TraceDelta,
) -> WarmSplit {
    let n_prev = prev_inst.len();
    let adj = overlap_adjacency(prev_inst);
    let mut disturbed_prev = vec![false; n_prev];
    // prev id → the new id carrying it (usize::MAX = removed).
    let mut carrier = vec![usize::MAX; n_prev];
    let mut disturbed: Vec<usize> = Vec::new();
    for (id, d) in delta.blocks.iter().enumerate() {
        match *d {
            BlockDelta::Unchanged { prev: p } => carrier[p] = id,
            BlockDelta::Resized { prev: p } => {
                carrier[p] = id;
                let old_top = prev.offsets[p] + prev_inst.blocks[p].size;
                let new_top = prev.offsets[p] + new_inst.blocks[id].size;
                // The grown band [old_top, new_top) collides iff some
                // time-overlapping neighbour starts inside it (the old
                // layout already keeps everything else disjoint).
                let collides = new_top > old_top
                    && adj
                        .neighbours(p)
                        .iter()
                        .any(|&j| (old_top..new_top).contains(&prev.offsets[j]));
                if collides {
                    disturbed_prev[p] = true;
                }
            }
            BlockDelta::Moved { prev: p } => {
                carrier[p] = id;
                disturbed_prev[p] = true;
            }
            BlockDelta::Added => disturbed.push(id),
        }
    }
    for &r in &delta.removed {
        disturbed_prev[r] = true;
    }
    close_upward(prev_inst, prev, &adj, &mut disturbed_prev);

    let mut offsets = vec![0u64; new_inst.len()];
    let mut kept: Vec<(u64, u64, u64)> = Vec::new();
    for (p, &id) in carrier.iter().enumerate() {
        if id == usize::MAX {
            continue; // removed
        }
        if disturbed_prev[p] {
            disturbed.push(id);
        } else {
            // Kept (possibly grown in place): the envelope rectangle uses
            // the new size at the old offset.
            let b = &new_inst.blocks[id];
            offsets[id] = prev.offsets[p];
            kept.push((b.alloc_at, b.free_at, prev.offsets[p] + b.size));
        }
    }
    disturbed.sort_unstable();
    WarmSplit {
        offsets,
        disturbed,
        kept,
    }
}

/// The indexed best-fit loop over the disturbed blocks, seeded from the
/// envelope (the hot warm-start path).
fn warm_place_indexed(
    new_inst: &DsaInstance,
    policy: Policy,
    offsets: &mut [u64],
    disturbed: &[usize],
    envelope: &[Seg],
) {
    let mut sky = IndexedSkyline::from_segments(envelope);
    let mut cands = CandidateIndex::with_blocks(new_inst, policy, disturbed, envelope);
    let mut remaining = disturbed.len();
    let mut changes = Changes::default();
    while remaining > 0 {
        let slot = sky.lowest_leftmost();
        let seg = sky.seg(slot);
        match cands.best(seg.t0) {
            Some(bid) => {
                let b = new_inst.blocks[bid];
                cands.place(bid);
                offsets[bid] = sky.place(slot, b.alloc_at, b.free_at, b.size, &mut changes);
                remaining -= 1;
            }
            // Nothing fits the chosen line; a single-segment skyline
            // always has candidates (every lifetime is contained in it).
            None => sky.lift(slot, &mut changes),
        }
        cands.apply(&changes);
    }
    debug_assert!(sky.check_invariants().is_ok());
}

/// Lift-and-replace local move for the anytime optimizer
/// ([`super::anytime`]): remove the `lifted` blocks from `current`, keep
/// every other placement at its offset, and re-run the indexed best-fit
/// loop over the lifted blocks on the kept placements' envelope — the
/// same keep/envelope/re-place machinery as a §4.3 warm re-solve, with
/// the lifted set chosen by the search instead of by a trace delta. The
/// result is always a valid assignment for `inst`; it improves on
/// `current` only when the re-placement packs the lifted set tighter
/// than where it sat (the caller gates on strict peak decrease).
pub(crate) fn lift_and_replace(
    inst: &DsaInstance,
    current: &Assignment,
    lifted: &[usize],
    policy: Policy,
) -> Assignment {
    debug_assert_eq!(current.offsets.len(), inst.len());
    let mut disturbed = lifted.to_vec();
    disturbed.sort_unstable();
    disturbed.dedup();
    if disturbed.is_empty() {
        return current.clone();
    }
    let mut is_lifted = vec![false; inst.len()];
    for &i in &disturbed {
        is_lifted[i] = true;
    }
    let kept: Vec<(u64, u64, u64)> = (0..inst.len())
        .filter(|&i| !is_lifted[i])
        .map(|i| {
            let b = &inst.blocks[i];
            (b.alloc_at, b.free_at, current.offsets[i] + b.size)
        })
        .collect();
    let mut offsets = current.offsets.clone();
    let envelope = kept_envelope(inst, &kept, &disturbed);
    warm_place_indexed(inst, policy, &mut offsets, &disturbed, &envelope);
    Assignment::from_offsets(inst, offsets)
}

/// The quadratic spec of the warm placement loop: reference `Vec` skyline
/// plus a linear rescan of the disturbed blocks per step.
fn warm_place_reference(
    new_inst: &DsaInstance,
    policy: Policy,
    offsets: &mut [u64],
    disturbed: &[usize],
    envelope: &[Seg],
) {
    let mut sky = Skyline::from_segments(envelope.to_vec());
    let mut unplaced = disturbed.to_vec();
    while !unplaced.is_empty() {
        let idx = sky.lowest_leftmost();
        let seg = sky.seg(idx);
        let mut best: Option<usize> = None;
        for &bid in &unplaced {
            let b = &new_inst.blocks[bid];
            if !seg.contains(b.alloc_at, b.free_at) {
                continue;
            }
            match best {
                None => best = Some(bid),
                Some(cur) => {
                    if policy.block_choice.prefer(b, &new_inst.blocks[cur]) {
                        best = Some(bid);
                    }
                }
            }
        }
        match best {
            Some(bid) => {
                let b = new_inst.blocks[bid];
                offsets[bid] = sky.place(idx, b.alloc_at, b.free_at, b.size);
                unplaced.retain(|&x| x != bid);
            }
            None => sky.lift(idx),
        }
    }
    debug_assert!(sky.check_invariants().is_ok());
}

/// Warm-start §4.3 re-solve with the paper's default policy (see
/// [`resolve_with`]).
pub fn resolve(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    new_inst: &DsaInstance,
    delta: &TraceDelta,
) -> Resolution {
    resolve_with(prev_inst, prev, new_inst, delta, Policy::default())
}

/// Warm-start incremental re-solve (§4.3 reoptimization). Size growth
/// that fits the slack above a block's old placement is absorbed *in
/// place* (offset reused, nothing re-solved — the common ratchet).
/// Colliding growth, lifetime shifts, additions, and removals disturb
/// their blocks plus the transitive closure of placements stacked above
/// them; every other placement keeps its offset, the kept placements'
/// envelope seeds the indexed skyline, and the best-fit loop re-runs
/// over the disturbed blocks only. Two fallbacks pay a full solve
/// instead (`warm: false`): a disturbance closure swallowing more than
/// half the instance, and — on ratchet-only deltas — a quality gate
/// that re-solves when the warm packing outgrows both the previous
/// arena and the new liveness bound, keeping the tighter packing. The
/// resulting guarantee: on ratchet-only deltas the returned peak never
/// exceeds `max(prev.peak, cold peak)` — a ratchet reopt never *grows*
/// the arena past a cold solve (the heuristic is not size-monotone, so
/// a packing already inside the held arena may sit marginally above a
/// fresh solve; that costs no memory).
pub fn resolve_with(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    new_inst: &DsaInstance,
    delta: &TraceDelta,
    policy: Policy,
) -> Resolution {
    resolve_impl(prev_inst, prev, new_inst, delta, policy, false)
}

/// Reference warm-start re-solve: identical keep/disturb/envelope logic,
/// but the placement loop runs on the reference `Vec` skyline with a
/// linear candidate rescan. [`resolve_with`] must match it byte for byte;
/// the reopt differential suite (`rust/tests/properties.rs`) pins the
/// equivalence.
pub fn resolve_reference_with(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    new_inst: &DsaInstance,
    delta: &TraceDelta,
    policy: Policy,
) -> Resolution {
    resolve_impl(prev_inst, prev, new_inst, delta, policy, true)
}

fn resolve_impl(
    prev_inst: &DsaInstance,
    prev: &Assignment,
    new_inst: &DsaInstance,
    delta: &TraceDelta,
    policy: Policy,
    reference: bool,
) -> Resolution {
    assert_eq!(
        prev.offsets.len(),
        prev_inst.len(),
        "assignment does not cover the previous instance"
    );
    assert_eq!(
        delta.blocks.len(),
        new_inst.len(),
        "delta does not cover the new instance"
    );
    if new_inst.is_empty() {
        return Resolution {
            assignment: Assignment {
                offsets: Vec::new(),
                peak: 0,
            },
            disturbed: 0,
            warm: true,
        };
    }
    let mut split = warm_split(prev_inst, prev, new_inst, delta);
    let disturbed = split.disturbed.len();
    // Hopeless warm-start: once the disturbance closure swallows most of
    // the instance, the incremental path cannot beat a fresh solve — go
    // straight to it instead of paying warm + gate + cold.
    if disturbed * 2 > new_inst.len() {
        let cold = if reference {
            solve_reference_with(new_inst, policy)
        } else {
            solve_with(new_inst, policy)
        };
        return Resolution {
            assignment: cold,
            disturbed: new_inst.len(),
            warm: false,
        };
    }
    if disturbed > 0 {
        let envelope = kept_envelope(new_inst, &split.kept, &split.disturbed);
        if reference {
            warm_place_reference(
                new_inst,
                policy,
                &mut split.offsets,
                &split.disturbed,
                &envelope,
            );
        } else {
            warm_place_indexed(
                new_inst,
                policy,
                &mut split.offsets,
                &split.disturbed,
                &envelope,
            );
        }
    }
    let assignment = Assignment::from_offsets(new_inst, split.offsets);
    debug_assert!(assignment.validate(new_inst).is_ok());

    if delta.is_ratchet_only(prev_inst, new_inst) {
        let bound = prev.peak.max(new_inst.lower_bound());
        if assignment.peak > bound {
            // Quality gate: warm regressed — pay one full solve, keep
            // whichever packing is tighter.
            let cold = if reference {
                solve_reference_with(new_inst, policy)
            } else {
                solve_with(new_inst, policy)
            };
            let best = if cold.peak < assignment.peak {
                cold
            } else {
                assignment
            };
            return Resolution {
                assignment: best,
                disturbed: new_inst.len(),
                warm: false,
            };
        }
    }
    Resolution {
        assignment,
        disturbed,
        warm: true,
    }
}

// ----- cross-bucket plan seeding ---------------------------------------------

/// The uniform integer size ratio `r` with `new.size == donor.size * r`
/// for every block, if one exists. Lifetimes are assumed positionally
/// equal (the caller checked the skeleton).
fn uniform_ratio(donor_inst: &DsaInstance, new_inst: &DsaInstance) -> Option<u64> {
    let first = donor_inst.blocks.first()?;
    if new_inst.blocks[0].size % first.size != 0 {
        return None;
    }
    let r = new_inst.blocks[0].size / first.size;
    if r == 0 {
        return None;
    }
    donor_inst
        .blocks
        .iter()
        .zip(&new_inst.blocks)
        .all(|(d, n)| d.size.checked_mul(r) == Some(n.size))
        .then_some(r)
}

/// Seed a solve of `new_inst` from a donor bucket's plan with the
/// paper's default policy (see [`seed_scaled_with`]).
pub fn seed_scaled(
    donor_inst: &DsaInstance,
    donor: &Assignment,
    new_inst: &DsaInstance,
) -> Resolution {
    seed_scaled_with(donor_inst, donor, new_inst, Policy::default())
}

/// Cross-bucket plan seeding: solve `new_inst` — the donor instance
/// scaled along the batch dimension — warm from the donor bucket's
/// assignment instead of from nothing.
///
/// Three regimes, in order:
///
/// 1. **Skeleton mismatch** (different block count or any positional
///    lifetime change): the structural fallback rule — a cold solve,
///    `warm: false`. Seeding never guesses across structures.
/// 2. **Uniform integer ratio** (`new.size == donor.size * r` for every
///    block): the exact O(n) transfer — every offset is multiplied by
///    `r`. Linear scaling preserves disjointness, so the packing is
///    valid by construction with peak exactly `donor.peak * r`, and the
///    best-fit heuristic is scale-equivariant, so this is the packing a
///    cold solve of the scaled instance would produce anyway (when the
///    donor came from the same heuristic) at none of the cost.
/// 3. **Fractional ratio** (ceiling-scaled sizes): the positional delta
///    is a pure size ratchet, so the [`resolve_with`] warm path applies
///    — in-place growth where slack allows, disturbance closure
///    otherwise, with the usual `> n/2` bail-out and ratchet quality
///    gate.
///
/// Guarantee (growth-only scaling, `num ≥ den`): the returned peak never
/// exceeds `max(ceil(donor.peak · num/den), cold peak)` —
/// `prop_seeded_build_sound` pins this for all four block-choice
/// policies.
pub fn seed_scaled_with(
    donor_inst: &DsaInstance,
    donor: &Assignment,
    new_inst: &DsaInstance,
    policy: Policy,
) -> Resolution {
    seed_scaled_impl(donor_inst, donor, new_inst, policy, false)
}

/// Reference cross-bucket seeding: identical regime selection, but the
/// cold and warm paths run on the quadratic reference formulation.
/// [`seed_scaled_with`] must match it byte for byte; the seeded-build
/// differential suite (`rust/tests/properties.rs`) pins the equivalence.
pub fn seed_scaled_reference_with(
    donor_inst: &DsaInstance,
    donor: &Assignment,
    new_inst: &DsaInstance,
    policy: Policy,
) -> Resolution {
    seed_scaled_impl(donor_inst, donor, new_inst, policy, true)
}

fn seed_scaled_impl(
    donor_inst: &DsaInstance,
    donor: &Assignment,
    new_inst: &DsaInstance,
    policy: Policy,
    reference: bool,
) -> Resolution {
    assert_eq!(
        donor.offsets.len(),
        donor_inst.len(),
        "assignment does not cover the donor instance"
    );
    if new_inst.is_empty() {
        return Resolution {
            assignment: Assignment {
                offsets: Vec::new(),
                peak: 0,
            },
            disturbed: 0,
            warm: true,
        };
    }
    let structural = donor_inst.len() != new_inst.len()
        || donor_inst
            .blocks
            .iter()
            .zip(&new_inst.blocks)
            .any(|(d, n)| (d.alloc_at, d.free_at) != (n.alloc_at, n.free_at));
    if structural {
        let cold = if reference {
            solve_reference_with(new_inst, policy)
        } else {
            solve_with(new_inst, policy)
        };
        return Resolution {
            assignment: cold,
            disturbed: new_inst.len(),
            warm: false,
        };
    }
    if let Some(r) = uniform_ratio(donor_inst, new_inst) {
        let offsets = donor.offsets.iter().map(|&o| o * r).collect();
        let assignment = Assignment::from_offsets(new_inst, offsets);
        debug_assert!(assignment.validate(new_inst).is_ok());
        return Resolution {
            assignment,
            disturbed: 0,
            warm: true,
        };
    }
    let delta = TraceDelta::diff(donor_inst, new_inst);
    if reference {
        resolve_reference_with(donor_inst, donor, new_inst, &delta, policy)
    } else {
        resolve_with(donor_inst, donor, new_inst, &delta, policy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsa::policies::BlockChoice;
    use crate::util::rng::Pcg32;

    #[test]
    fn empty_instance() {
        let sol = solve(&DsaInstance::new(vec![]));
        assert_eq!(sol.peak, 0);
        assert_eq!(solve_reference(&DsaInstance::new(vec![])).peak, 0);
    }

    #[test]
    fn single_block() {
        let inst = DsaInstance::from_triples(&[(64, 0, 3)]);
        let sol = solve(&inst);
        assert_eq!(sol.offsets, vec![0]);
        assert_eq!(sol.peak, 64);
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn disjoint_lifetimes_share_space() {
        let inst = DsaInstance::from_triples(&[(100, 0, 2), (100, 2, 4), (100, 4, 6)]);
        let sol = solve(&inst);
        assert_eq!(sol.peak, 100, "serial blocks must all reuse offset 0");
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn overlapping_lifetimes_stack() {
        let inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        // Liveness LB is 30 and best-fit achieves it here.
        assert_eq!(sol.peak, 30);
    }

    #[test]
    fn reaches_liveness_bound_on_nested_pattern() {
        // Nested lifetimes (LIFO order, like fwd activations freed in bwd):
        // best-fit should pack these perfectly.
        let inst = DsaInstance::from_triples(&[
            (8, 0, 10),
            (4, 1, 9),
            (2, 2, 8),
            (1, 3, 7),
        ]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        assert_eq!(sol.peak, inst.liveness_lower_bound());
    }

    #[test]
    fn longest_lifetime_placed_first_at_bottom() {
        let inst = DsaInstance::from_triples(&[(5, 2, 4), (5, 0, 10)]);
        let sol = solve(&inst);
        // Block 1 has the longer lifetime → goes to offset 0.
        assert_eq!(sol.offsets[1], 0);
        assert_eq!(sol.offsets[0], 5);
    }

    #[test]
    fn lift_path_is_exercised() {
        // After placing the long block, the lowest line is a narrow valley
        // no remaining block fits into → the heuristic must lift.
        let inst = DsaInstance::from_triples(&[(4, 0, 9), (2, 2, 12), (1, 0, 12)]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        assert_eq!(sol, solve_reference(&inst));
    }

    #[test]
    fn all_policies_produce_valid_packings() {
        let mut rng = Pcg32::seeded(17);
        let triples: Vec<(u64, u64, u64)> = (0..120)
            .map(|_| {
                let a = rng.range(0, 300);
                (rng.range(1, 4096), a, a + rng.range(1, 80))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let lb = inst.lower_bound();
        for choice in BlockChoice::ALL {
            let sol = solve_with(&inst, Policy { block_choice: choice });
            sol.validate(&inst)
                .unwrap_or_else(|e| panic!("policy {}: {e}", choice.name()));
            assert!(sol.peak >= lb);
            assert!(sol.peak <= inst.total_size());
        }
    }

    #[test]
    fn indexed_matches_reference_on_random_instances() {
        let mut rng = Pcg32::seeded(0xbe5f);
        for case in 0..30 {
            let n = rng.range_usize(1, 90);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 250);
                    (rng.range(1, 4096), a, a + rng.range(1, 60))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            for choice in BlockChoice::ALL {
                let policy = Policy { block_choice: choice };
                let indexed = solve_with(&inst, policy);
                let reference = solve_reference_with(&inst, policy);
                assert_eq!(
                    indexed,
                    reference,
                    "case {case}: policy {} diverged",
                    choice.name()
                );
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let inst = DsaInstance::from_triples(&[
            (7, 0, 5),
            (7, 0, 5),
            (3, 1, 9),
            (9, 4, 11),
            (2, 6, 8),
        ]);
        let a = solve(&inst);
        let b = solve(&inst);
        assert_eq!(a, b);
    }

    // ----- warm-start resolve ------------------------------------------------

    #[test]
    fn delta_diff_classifies_positionally() {
        let prev = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)]);
        let new = DsaInstance::from_triples(&[(10, 0, 4), (32, 2, 6), (5, 5, 8), (9, 1, 3)]);
        let d = TraceDelta::diff(&prev, &new);
        assert_eq!(
            d.blocks,
            vec![
                BlockDelta::Unchanged { prev: 0 },
                BlockDelta::Resized { prev: 1 },
                BlockDelta::Moved { prev: 2 },
                BlockDelta::Added,
            ]
        );
        assert!(d.removed.is_empty());
        assert_eq!(d.changed(), 3);
        assert!(!d.is_ratchet_only(&prev, &new));

        let shorter = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6)]);
        let d = TraceDelta::diff(&prev, &shorter);
        assert_eq!(d.removed, vec![2]);
        assert!(!d.is_ratchet_only(&prev, &shorter));

        let ratchet = DsaInstance::from_triples(&[(10, 0, 4), (28, 2, 6), (5, 5, 7)]);
        let d = TraceDelta::diff(&prev, &ratchet);
        assert!(d.is_ratchet_only(&prev, &ratchet));
        assert_eq!(d.changed(), 1);

        let shrink = DsaInstance::from_triples(&[(10, 0, 4), (2, 2, 6), (5, 5, 7)]);
        let d = TraceDelta::diff(&prev, &shrink);
        assert!(!d.is_ratchet_only(&prev, &shrink), "shrinks are not ratchets");
    }

    #[test]
    fn resolve_grows_in_place_when_slack_allows() {
        // Block 2 shares no lifetime with anything: its growth fits the
        // open slack above it, so the ratchet is in-place — nothing is
        // re-solved at all.
        let prev_inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 10, 14)]);
        let prev = solve(&prev_inst);
        let new_inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (40, 10, 14)]);
        let delta = TraceDelta::diff(&prev_inst, &new_inst);
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        r.assignment.validate(&new_inst).unwrap();
        assert!(r.warm);
        assert_eq!(r.disturbed, 0, "slack growth disturbs nothing");
        assert_eq!(r.assignment.offsets, prev.offsets, "every offset reused");
        assert_eq!(r.assignment.peak, 40, "the arena just grows");
    }

    #[test]
    fn resolve_re_places_colliding_growth_only() {
        // Previous layout: block 1 at the floor, block 0 stacked above it
        // (they overlap in [2,4)); blocks 2 and 3 live alone at later
        // times. Growing block 1 into block 0's offset re-places exactly
        // that stack; blocks 2 and 3 never move.
        let prev_inst =
            DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 10, 14), (7, 20, 24)]);
        let prev = solve(&prev_inst);
        assert_eq!(prev.offsets, vec![20, 0, 0, 0]);
        let new_inst =
            DsaInstance::from_triples(&[(10, 0, 4), (25, 2, 6), (5, 10, 14), (7, 20, 24)]);
        let delta = TraceDelta::diff(&prev_inst, &new_inst);
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        r.assignment.validate(&new_inst).unwrap();
        assert!(r.warm);
        assert_eq!(r.disturbed, 2, "the grown block and its stack re-place");
        assert_eq!(r.assignment.offsets[2], prev.offsets[2]);
        assert_eq!(
            r.assignment.offsets[3], prev.offsets[3],
            "time-disjoint placements are untouched"
        );
        assert_eq!(r.assignment.peak, 35, "liveness-tight after the re-pack");
    }

    #[test]
    fn resolve_recompacts_the_disturbed_stack() {
        // Three stacked blocks; growing the bottom one disturbs the whole
        // stack (transitive upward closure). With everything disturbed the
        // hopeless-warm bailout pays one fresh solve outright — and the
        // result is still liveness-tight rather than stacked on stale
        // placements.
        let prev_inst = DsaInstance::from_triples(&[(10, 0, 8), (5, 1, 7), (2, 2, 6)]);
        let prev = solve(&prev_inst);
        let new_inst = DsaInstance::from_triples(&[(16, 0, 8), (5, 1, 7), (2, 2, 6)]);
        let delta = TraceDelta::diff(&prev_inst, &new_inst);
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        r.assignment.validate(&new_inst).unwrap();
        assert_eq!(r.disturbed, 3, "the stack above the grown block re-solves");
        assert_eq!(r.assignment.peak, 23, "liveness-tight after recompaction");
        assert!(!r.warm, "a fully-disturbed instance skips the warm path");
    }

    #[test]
    fn resolve_reclaims_removed_space() {
        let prev_inst = DsaInstance::from_triples(&[(10, 0, 8), (5, 1, 7), (2, 2, 6)]);
        let prev = solve(&prev_inst);
        // The bottom block vanishes (shorter propagation): new 0 ← prev 1
        // and new 1 ← prev 2 survive unchanged, but the upward closure of
        // the removed floor block re-places them, compacting the stack.
        let new_inst = DsaInstance::from_triples(&[(5, 1, 7), (2, 2, 6)]);
        let delta = TraceDelta {
            blocks: vec![
                BlockDelta::Unchanged { prev: 1 },
                BlockDelta::Unchanged { prev: 2 },
            ],
            removed: vec![0],
        };
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        r.assignment.validate(&new_inst).unwrap();
        assert_eq!(r.disturbed, 2, "removal disturbs the stack above it");
        assert_eq!(r.assignment.peak, 7, "freed floor space is reused");
    }

    #[test]
    fn resolve_empty_new_instance() {
        let prev_inst = DsaInstance::from_triples(&[(10, 0, 4)]);
        let prev = solve(&prev_inst);
        let new_inst = DsaInstance::new(vec![]);
        let delta = TraceDelta::diff(&prev_inst, &new_inst);
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        assert_eq!(r.assignment.peak, 0);
        assert_eq!(r.disturbed, 0);
    }

    #[test]
    fn resolve_from_empty_previous_places_everything() {
        let prev_inst = DsaInstance::new(vec![]);
        let prev = solve(&prev_inst);
        let new_inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6)]);
        let delta = TraceDelta::diff(&prev_inst, &new_inst);
        let r = resolve(&prev_inst, &prev, &new_inst, &delta);
        r.assignment.validate(&new_inst).unwrap();
        assert_eq!(r.disturbed, 2);
    }

    #[test]
    fn lift_and_replace_is_valid_and_keeps_unlifted_offsets() {
        let mut rng = Pcg32::seeded(0x11f7);
        for case in 0..20 {
            let n = rng.range_usize(4, 40);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 100);
                    (rng.range(1, 512), a, a + rng.range(1, 30))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            let current = solve(&inst);
            let lifted: Vec<usize> = (0..n).filter(|_| rng.bool(0.3)).collect();
            for choice in BlockChoice::ALL {
                let moved =
                    lift_and_replace(&inst, &current, &lifted, Policy { block_choice: choice });
                moved
                    .validate(&inst)
                    .unwrap_or_else(|e| panic!("case {case} policy {}: {e}", choice.name()));
                for i in 0..n {
                    if !lifted.contains(&i) {
                        assert_eq!(
                            moved.offsets[i], current.offsets[i],
                            "case {case}: unlifted block {i} moved"
                        );
                    }
                }
            }
            // Lifting nothing is the identity.
            assert_eq!(lift_and_replace(&inst, &current, &[], Policy::default()), current);
        }
    }

    // ----- cross-bucket plan seeding -----------------------------------------

    #[test]
    fn seed_scaled_uniform_ratio_transfers_offsets_exactly() {
        let donor_inst =
            DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7), (7, 10, 12)]);
        let donor = solve(&donor_inst);
        let scaled =
            DsaInstance::from_triples(&[(40, 0, 4), (80, 2, 6), (20, 5, 7), (28, 10, 12)]);
        let r = seed_scaled(&donor_inst, &donor, &scaled);
        r.assignment.validate(&scaled).unwrap();
        assert!(r.warm);
        assert_eq!(r.disturbed, 0, "the exact transfer re-places nothing");
        let expected: Vec<u64> = donor.offsets.iter().map(|&o| o * 4).collect();
        assert_eq!(r.assignment.offsets, expected);
        assert_eq!(r.assignment.peak, donor.peak * 4);
        // Scale-equivariance: the transfer equals the cold solve.
        assert_eq!(r.assignment, solve(&scaled));
    }

    #[test]
    fn seed_scaled_identity_ratio_reuses_the_plan() {
        let donor_inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6)]);
        let donor = solve(&donor_inst);
        let r = seed_scaled(&donor_inst, &donor, &donor_inst.clone());
        assert!(r.warm);
        assert_eq!(r.assignment, donor);
    }

    #[test]
    fn seed_scaled_fractional_ratio_rides_the_warm_path() {
        // Sizes ceil-scaled by 3/2: no uniform integer ratio, but the
        // delta is a pure ratchet, so the warm resolve applies — and the
        // ratchet gate bounds the peak by max(donor peak, cold peak).
        let donor_inst = DsaInstance::from_triples(&[(10, 0, 4), (21, 2, 6), (5, 10, 14)]);
        let donor = solve(&donor_inst);
        let scaled = DsaInstance::from_triples(&[(15, 0, 4), (32, 2, 6), (8, 10, 14)]);
        let r = seed_scaled(&donor_inst, &donor, &scaled);
        r.assignment.validate(&scaled).unwrap();
        let cold = solve(&scaled);
        let scaled_donor_peak = (donor.peak * 3 + 1) / 2;
        assert!(r.assignment.peak <= cold.peak.max(scaled_donor_peak));
        assert_eq!(
            r,
            seed_scaled_reference_with(&donor_inst, &donor, &scaled, Policy::default())
        );
    }

    #[test]
    fn seed_scaled_structural_mismatch_solves_cold() {
        let donor_inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6)]);
        let donor = solve(&donor_inst);
        // A shifted lifetime: positions no longer correspond.
        let other = DsaInstance::from_triples(&[(10, 0, 4), (20, 3, 6)]);
        let r = seed_scaled(&donor_inst, &donor, &other);
        assert!(!r.warm, "skeleton mismatch must fall back to cold");
        assert_eq!(r.disturbed, other.len());
        assert_eq!(r.assignment, solve(&other));
        // A different block count likewise.
        let longer = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (1, 0, 1)]);
        assert!(!seed_scaled(&donor_inst, &donor, &longer).warm);
    }

    #[test]
    fn seed_scaled_empty_target() {
        let donor_inst = DsaInstance::from_triples(&[(10, 0, 4)]);
        let donor = solve(&donor_inst);
        let r = seed_scaled(&donor_inst, &donor, &DsaInstance::new(vec![]));
        assert_eq!(r.assignment.peak, 0);
        assert!(r.warm);
    }

    #[test]
    fn resolve_matches_reference_on_random_deltas() {
        let mut rng = Pcg32::seeded(0x4e50);
        for case in 0..40 {
            let n = rng.range_usize(1, 50);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 120);
                    (rng.range(1, 2048), a, a + rng.range(1, 40))
                })
                .collect();
            let prev_inst = DsaInstance::from_triples(&triples);
            // Random delta: ratchet some, shift some, append/drop a tail.
            let mut mutated = triples.clone();
            for t in mutated.iter_mut() {
                if rng.bool(0.25) {
                    t.0 += rng.range(1, 2048);
                }
                if rng.bool(0.1) {
                    let a = rng.range(0, 120);
                    t.1 = a;
                    t.2 = a + rng.range(1, 40);
                }
            }
            if rng.bool(0.3) {
                for _ in 0..rng.range_usize(1, 5) {
                    let a = rng.range(0, 120);
                    mutated.push((rng.range(1, 2048), a, a + rng.range(1, 40)));
                }
            } else if rng.bool(0.3) && mutated.len() > 1 {
                mutated.truncate(mutated.len() - rng.range_usize(1, mutated.len() - 1));
            }
            let new_inst = DsaInstance::from_triples(&mutated);
            let delta = TraceDelta::diff(&prev_inst, &new_inst);
            for choice in BlockChoice::ALL {
                let policy = Policy { block_choice: choice };
                let prev = solve_with(&prev_inst, policy);
                let warm = resolve_with(&prev_inst, &prev, &new_inst, &delta, policy);
                warm.assignment
                    .validate(&new_inst)
                    .unwrap_or_else(|e| panic!("case {case} policy {}: {e}", choice.name()));
                let reference =
                    resolve_reference_with(&prev_inst, &prev, &new_inst, &delta, policy);
                assert_eq!(
                    warm, reference,
                    "case {case}: policy {} diverged from the reference warm path",
                    choice.name()
                );
            }
        }
    }
}
