//! The paper's best-fit heuristic for DSA (§3.2, after Burke et al. 2004).
//!
//! Repeat until every block is placed:
//!
//! 1. choose the lowest (leftmost on ties) offset line of the skyline;
//! 2. among unplaced blocks whose lifetime fits the line's span, place the
//!    one with the longest lifetime at that offset;
//! 3. if no block fits, *lift* the line into its lowest adjacent line.
//!
//! [`solve`]/[`solve_with`] run the indexed hot path: an
//! [`IndexedSkyline`] makes step 1 an O(log S) ordered-set minimum and
//! the splits/merges of steps 2–3 O(log S) amortized, while a
//! [`CandidateIndex`] keeps the per-window unplaced blocks ordered by the
//! policy key so step 2 is one set lookup instead of a rescan of every
//! block in the window. Plans that build lazily on the serving path (a
//! `PlanRegistry` miss solves inside the request loop) ride this path.
//!
//! [`solve_reference`]/[`solve_reference_with`] keep the original
//! quadratic formulation — an O(S) segment scan per step over the `Vec`
//! skyline, and a candidate loop that rescans already-placed blocks in
//! its alloc-tick window. The two are semantically identical by
//! construction (same chosen line, same chosen block, same offsets, byte
//! for byte); `rust/tests/properties.rs` pins the equivalence across all
//! policies, and `benches/bench_solver_scale.rs` pins the speedup
//! (targets in ROADMAP.md `## Perf targets`).

use super::candidates::CandidateIndex;
use super::indexed::{Changes, IndexedSkyline};
use super::policies::Policy;
use super::problem::DsaInstance;
use super::skyline::Skyline;
use super::solution::Assignment;

/// Solve with the paper's default policy (longest lifetime).
pub fn solve(inst: &DsaInstance) -> Assignment {
    solve_with(inst, Policy::default())
}

/// Solve with an explicit block-choice policy (ablations), on the
/// indexed hot path.
pub fn solve_with(inst: &DsaInstance, policy: Policy) -> Assignment {
    if inst.is_empty() {
        return Assignment {
            offsets: Vec::new(),
            peak: 0,
        };
    }

    let n = inst.len();
    let mut offsets = vec![0u64; n];
    let mut remaining = n;
    let mut sky = IndexedSkyline::new(inst.horizon());
    let mut cands = CandidateIndex::new(inst, policy);
    let mut changes = Changes::default();

    while remaining > 0 {
        let slot = sky.lowest_leftmost();
        let seg = sky.seg(slot);
        // The window's candidate set mirrors the segment exactly, so the
        // policy winner is one ordered-set lookup.
        match cands.best(seg.t0) {
            Some(bid) => {
                let b = inst.blocks[bid];
                cands.place(bid);
                offsets[bid] = sky.place(slot, b.alloc_at, b.free_at, b.size, &mut changes);
                remaining -= 1;
            }
            // No unplaced block fits the line: lift it (§3.2). A
            // single-segment skyline always has candidates — every
            // lifetime is contained in the full horizon — so lift never
            // sees one.
            None => sky.lift(slot, &mut changes),
        }
        cands.apply(&changes);
    }

    debug_assert!(sky.check_invariants().is_ok());
    Assignment::from_offsets(inst, offsets)
}

/// Reference solver: the paper's default policy on the original
/// quadratic formulation. Kept verbatim for differential testing of the
/// indexed path and as the readable spec of §3.2.
pub fn solve_reference(inst: &DsaInstance) -> Assignment {
    solve_reference_with(inst, Policy::default())
}

/// Reference solver with an explicit block-choice policy.
pub fn solve_reference_with(inst: &DsaInstance, policy: Policy) -> Assignment {
    if inst.is_empty() {
        return Assignment {
            offsets: Vec::new(),
            peak: 0,
        };
    }

    let n = inst.len();
    let mut offsets = vec![0u64; n];
    let mut placed = vec![false; n];
    let mut remaining = n;

    // Blocks sorted by alloc tick: a segment [t0, t1) can only host blocks
    // with alloc_at in [t0, t1), so each candidate scan touches just that
    // window instead of all n blocks.
    let mut by_alloc: Vec<usize> = (0..n).collect();
    by_alloc.sort_unstable_by_key(|&i| inst.blocks[i].alloc_at);
    let alloc_keys: Vec<u64> = by_alloc.iter().map(|&i| inst.blocks[i].alloc_at).collect();

    let mut sky = Skyline::new(inst.horizon());

    while remaining > 0 {
        let idx = sky.lowest_leftmost();
        let seg = sky.seg(idx);

        // Scan candidates with alloc_at ∈ [seg.t0, seg.t1).
        let lo = alloc_keys.partition_point(|&a| a < seg.t0);
        let hi = alloc_keys.partition_point(|&a| a < seg.t1);
        let mut best: Option<usize> = None;
        for &bid in &by_alloc[lo..hi] {
            if placed[bid] {
                continue;
            }
            let b = &inst.blocks[bid];
            if b.free_at > seg.t1 {
                continue; // lifetime exits the span
            }
            match best {
                None => best = Some(bid),
                Some(cur) => {
                    if policy.block_choice.prefer(b, &inst.blocks[cur]) {
                        best = Some(bid);
                    }
                }
            }
        }

        match best {
            Some(bid) => {
                let b = inst.blocks[bid];
                offsets[bid] = sky.place(idx, b.alloc_at, b.free_at, b.size);
                placed[bid] = true;
                remaining -= 1;
            }
            None => sky.lift(idx),
        }
    }

    debug_assert!(sky.check_invariants().is_ok());
    Assignment::from_offsets(inst, offsets)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dsa::policies::BlockChoice;
    use crate::util::rng::Pcg32;

    #[test]
    fn empty_instance() {
        let sol = solve(&DsaInstance::new(vec![]));
        assert_eq!(sol.peak, 0);
        assert_eq!(solve_reference(&DsaInstance::new(vec![])).peak, 0);
    }

    #[test]
    fn single_block() {
        let inst = DsaInstance::from_triples(&[(64, 0, 3)]);
        let sol = solve(&inst);
        assert_eq!(sol.offsets, vec![0]);
        assert_eq!(sol.peak, 64);
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn disjoint_lifetimes_share_space() {
        let inst = DsaInstance::from_triples(&[(100, 0, 2), (100, 2, 4), (100, 4, 6)]);
        let sol = solve(&inst);
        assert_eq!(sol.peak, 100, "serial blocks must all reuse offset 0");
        sol.validate(&inst).unwrap();
    }

    #[test]
    fn overlapping_lifetimes_stack() {
        let inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        // Liveness LB is 30 and best-fit achieves it here.
        assert_eq!(sol.peak, 30);
    }

    #[test]
    fn reaches_liveness_bound_on_nested_pattern() {
        // Nested lifetimes (LIFO order, like fwd activations freed in bwd):
        // best-fit should pack these perfectly.
        let inst = DsaInstance::from_triples(&[
            (8, 0, 10),
            (4, 1, 9),
            (2, 2, 8),
            (1, 3, 7),
        ]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        assert_eq!(sol.peak, inst.liveness_lower_bound());
    }

    #[test]
    fn longest_lifetime_placed_first_at_bottom() {
        let inst = DsaInstance::from_triples(&[(5, 2, 4), (5, 0, 10)]);
        let sol = solve(&inst);
        // Block 1 has the longer lifetime → goes to offset 0.
        assert_eq!(sol.offsets[1], 0);
        assert_eq!(sol.offsets[0], 5);
    }

    #[test]
    fn lift_path_is_exercised() {
        // After placing the long block, the lowest line is a narrow valley
        // no remaining block fits into → the heuristic must lift.
        let inst = DsaInstance::from_triples(&[(4, 0, 9), (2, 2, 12), (1, 0, 12)]);
        let sol = solve(&inst);
        sol.validate(&inst).unwrap();
        assert_eq!(sol, solve_reference(&inst));
    }

    #[test]
    fn all_policies_produce_valid_packings() {
        let mut rng = Pcg32::seeded(17);
        let triples: Vec<(u64, u64, u64)> = (0..120)
            .map(|_| {
                let a = rng.range(0, 300);
                (rng.range(1, 4096), a, a + rng.range(1, 80))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let lb = inst.lower_bound();
        for choice in BlockChoice::ALL {
            let sol = solve_with(&inst, Policy { block_choice: choice });
            sol.validate(&inst)
                .unwrap_or_else(|e| panic!("policy {}: {e}", choice.name()));
            assert!(sol.peak >= lb);
            assert!(sol.peak <= inst.total_size());
        }
    }

    #[test]
    fn indexed_matches_reference_on_random_instances() {
        let mut rng = Pcg32::seeded(0xbe5f);
        for case in 0..30 {
            let n = rng.range_usize(1, 90);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 250);
                    (rng.range(1, 4096), a, a + rng.range(1, 60))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            for choice in BlockChoice::ALL {
                let policy = Policy { block_choice: choice };
                let indexed = solve_with(&inst, policy);
                let reference = solve_reference_with(&inst, policy);
                assert_eq!(
                    indexed,
                    reference,
                    "case {case}: policy {} diverged",
                    choice.name()
                );
            }
        }
    }

    #[test]
    fn deterministic_across_runs() {
        let inst = DsaInstance::from_triples(&[
            (7, 0, 5),
            (7, 0, 5),
            (3, 1, 9),
            (9, 4, 11),
            (2, 6, 8),
        ]);
        let a = solve(&inst);
        let b = solve(&inst);
        assert_eq!(a, b);
    }
}
