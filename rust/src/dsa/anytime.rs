//! Anytime improvement of a DSA packing — background search beyond
//! best-fit (ROADMAP.md `## Anytime improvement`).
//!
//! The §3.2 heuristic is one greedy pass: good, fast, and 5–20% off the
//! certified optimum on the small instances where the paper's §5.2 CPLEX
//! runs (our [`super::exact`]) can prove it. This module spends a
//! configurable time slice turning that slack into reclaimed arena
//! bytes, starting from an incumbent [`Assignment`] and escalating
//! through three search layers:
//!
//! 1. **policy-perturbation restarts** — a fresh indexed solve per
//!    [`BlockChoice`] order (the four §3.2 ablations), which also makes
//!    the result never worse than a cold default-policy re-pack;
//! 2. **lift-and-replace local moves** — seeded random lifts of the
//!    peak-critical blocks (plus a diversification band), re-placed on
//!    the kept placements' envelope via the warm-start machinery
//!    ([`super::bestfit`]'s `lift_and_replace`), under a random policy;
//! 3. **bounded branch-and-bound dives** — [`super::exact::dive`]
//!    seeded from the current incumbent, on instances small enough for
//!    the adjacency precompute to be worth the slice; a completed dive
//!    certifies the incumbent optimal and ends the search.
//!
//! **Monotone-incumbent guarantee**: every published step is a
//! validated no-overlap assignment whose peak is *strictly* below the
//! previous incumbent's. Cancellation at any instant — the budget
//! expiring mid-phase, the serving engine dropping the result — yields
//! a sound plan, and the final result's peak never exceeds the seed's.
//! The search never publishes a peak below the instance's lower bound,
//! and sets `proved_optimal` only when a dive exhausts the space or the
//! bound is met.
//!
//! The serving integration (`plan/engine.rs`) runs [`improve`] on the
//! background re-pack thread — drift-triggered instead of a fixed
//! cadence — and swaps results in through the existing tightness-gated
//! iteration-boundary mechanism, so serving never blocks on the search.

use super::bestfit;
use super::exact;
use super::policies::{BlockChoice, Policy};
use super::problem::DsaInstance;
use super::solution::Assignment;
use crate::util::rng::Pcg32;
use std::time::{Duration, Instant};

/// Instances larger than this skip the branch-and-bound dives: the
/// dive's O(n²) adjacency precompute alone would eat a serving-sized
/// slice, and the restart/lift layers carry the search at scale.
const DIVE_MAX_BLOCKS: usize = 512;

/// Unimproved lift-and-replace moves tolerated before the slice hands
/// over to the next layer.
const STALL_LIMIT: usize = 16;

/// Cap on blocks lifted per local move, keeping each re-place a small
/// fraction of a full solve even on 4k-block instances.
const MAX_LIFT: usize = 192;

/// Outcome of one anytime search slice.
#[derive(Debug, Clone)]
pub struct AnytimeResult {
    /// The final incumbent: the (validated) seed or a strictly tighter
    /// packing. Never worse than the seed.
    pub assignment: Assignment,
    /// Published improvement steps (each one a validated assignment
    /// strictly below the previous incumbent's peak).
    pub steps: u64,
    /// Arena bytes reclaimed relative to the starting incumbent.
    pub reclaimed: u64,
    /// True when a completed dive certified the incumbent optimal, or
    /// the instance lower bound was met.
    pub proved_optimal: bool,
    /// Branch-and-bound nodes expanded across all dives.
    pub nodes: u64,
    pub elapsed: Duration,
}

/// Spend up to `budget` improving `incumbent` (see the module docs).
///
/// A zero budget returns the seed untouched — the deadline is polled
/// before every candidate solve, move, and dive.
pub fn improve(inst: &DsaInstance, incumbent: &Assignment, budget: Duration) -> AnytimeResult {
    improve_observed(inst, incumbent, budget, 0x9e3779b97f4a7c15, |_| {})
}

/// [`improve`] with an explicit perturbation seed and an observer
/// called on every published incumbent, in publication order — the
/// hook the monotonicity and differential suites pin the
/// cancellation-at-any-instant guarantee through.
pub fn improve_observed(
    inst: &DsaInstance,
    incumbent: &Assignment,
    budget: Duration,
    seed: u64,
    mut on_publish: impl FnMut(&Assignment),
) -> AnytimeResult {
    let start = Instant::now();
    let deadline = start + budget;
    let lb = inst.lower_bound();

    // A seed that does not cover the instance (or overlaps) cannot be
    // returned — fall back to a fresh heuristic solve so cancellation
    // still yields a sound plan. The engine always hands in its live
    // (valid) assignment, so this path is defensive.
    let mut best =
        if incumbent.offsets.len() == inst.len() && incumbent.validate(inst).is_ok() {
            incumbent.clone()
        } else {
            bestfit::solve(inst)
        };
    let initial_peak = best.peak;
    let mut steps = 0u64;
    let mut nodes = 0u64;
    let mut proved = best.peak <= lb;

    // Layer 1: policy-perturbation restarts across the four orders.
    if !proved {
        for choice in BlockChoice::ALL {
            if Instant::now() >= deadline {
                break;
            }
            let cand = bestfit::solve_with(inst, Policy { block_choice: choice });
            publish(inst, cand, &mut best, &mut steps, &mut on_publish);
            if best.peak <= lb {
                proved = true;
                break;
            }
        }
    }

    // Layers 2+3, alternating until the budget, a certificate, or a
    // full unimproved round.
    let mut rng = Pcg32::seeded(seed);
    let mut last_dive_peak: Option<u64> = None;
    loop {
        if proved || Instant::now() >= deadline {
            break;
        }
        let mut round_improved = false;

        // Layer 2: lift-and-replace local moves until a stall.
        let mut stall = 0usize;
        while stall < STALL_LIMIT && Instant::now() < deadline {
            let lifted = pick_lifted(&mut rng, inst, &best);
            let choice = BlockChoice::ALL[rng.range_usize(0, 3)];
            let cand =
                bestfit::lift_and_replace(inst, &best, &lifted, Policy { block_choice: choice });
            if publish(inst, cand, &mut best, &mut steps, &mut on_publish) {
                round_improved = true;
                stall = 0;
            } else {
                stall += 1;
            }
            if best.peak <= lb {
                proved = true;
                break;
            }
        }

        // Layer 3: one bounded dive, skipped while the incumbent is
        // unchanged since the last dive (the search is deterministic in
        // its seed incumbent, so repeating it cannot help).
        if !proved
            && inst.len() <= DIVE_MAX_BLOCKS
            && last_dive_peak != Some(best.peak)
            && Instant::now() < deadline
        {
            last_dive_peak = Some(best.peak);
            let d = exact::dive(inst, &best, deadline, u64::MAX);
            nodes += d.nodes;
            if publish(inst, d.assignment, &mut best, &mut steps, &mut on_publish) {
                round_improved = true;
            }
            if d.completed {
                proved = true;
            }
        }

        if !round_improved {
            break; // exhausted: more of the same randomness cannot pay.
        }
    }

    AnytimeResult {
        reclaimed: initial_peak - best.peak,
        assignment: best,
        steps,
        proved_optimal: proved,
        nodes,
        elapsed: start.elapsed(),
    }
}

/// Publish `cand` iff it is a validated strict improvement; returns
/// whether it was published. This is the single gate behind the
/// monotone-incumbent guarantee.
fn publish(
    inst: &DsaInstance,
    cand: Assignment,
    best: &mut Assignment,
    steps: &mut u64,
    on_publish: &mut impl FnMut(&Assignment),
) -> bool {
    if cand.peak < best.peak && cand.validate(inst).is_ok() {
        *best = cand;
        *steps += 1;
        on_publish(best);
        true
    } else {
        false
    }
}

/// Choose a lift set for one local move: every peak-critical block
/// (its top *is* the arena high-water mark — nothing improves unless
/// those move), a random sample of the top quarter of the packing, and
/// a thin random diversification band, capped at [`MAX_LIFT`].
fn pick_lifted(rng: &mut Pcg32, inst: &DsaInstance, best: &Assignment) -> Vec<usize> {
    let peak = best.peak;
    let band = peak - peak / 4;
    let mut lifted = Vec::new();
    for i in 0..inst.len() {
        if lifted.len() >= MAX_LIFT {
            break;
        }
        let top = best.offsets[i] + inst.blocks[i].size;
        if top == peak || (top > band && rng.bool(0.35)) || rng.bool(0.02) {
            lifted.push(i);
        }
    }
    lifted
}

#[cfg(test)]
mod tests {
    use super::*;

    const BUDGET: Duration = Duration::from_millis(250);

    fn random_instance(seed: u64, n: usize) -> DsaInstance {
        let mut rng = Pcg32::seeded(seed);
        let triples: Vec<(u64, u64, u64)> = (0..n)
            .map(|_| {
                let a = rng.range(0, 60);
                (rng.range(1, 256), a, a + rng.range(1, 25))
            })
            .collect();
        DsaInstance::from_triples(&triples)
    }

    #[test]
    fn zero_budget_returns_the_seed_untouched() {
        let inst = random_instance(3, 30);
        let seed = bestfit::solve(&inst);
        let r = improve(&inst, &seed, Duration::from_nanos(0));
        assert_eq!(r.assignment.offsets, seed.offsets);
        assert_eq!((r.steps, r.reclaimed, r.nodes), (0, 0, 0));
    }

    #[test]
    fn empty_instance_is_proved_immediately() {
        let inst = DsaInstance::from_triples(&[]);
        let seed = bestfit::solve(&inst);
        let r = improve(&inst, &seed, BUDGET);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 0);
        assert_eq!(r.steps, 0);
    }

    #[test]
    fn every_published_step_is_valid_and_strictly_tighter() {
        let inst = random_instance(7, 40);
        let seed = bestfit::solve(&inst);
        let mut peaks = vec![seed.peak];
        let r = improve_observed(&inst, &seed, BUDGET, 0xfeed, |a| {
            a.validate(&inst).unwrap();
            assert!(a.peak < *peaks.last().unwrap(), "publish must be strict");
            peaks.push(a.peak);
        });
        assert_eq!(r.steps as usize, peaks.len() - 1);
        assert_eq!(r.assignment.peak, *peaks.last().unwrap());
        assert_eq!(r.reclaimed, seed.peak - r.assignment.peak);
        assert!(r.assignment.peak >= inst.lower_bound());
    }

    #[test]
    fn invalid_seed_falls_back_to_a_fresh_solve() {
        let inst = random_instance(11, 12);
        let bogus = Assignment {
            offsets: vec![0; inst.len()], // everything at 0: overlaps
            peak: 1,
        };
        let r = improve(&inst, &bogus, BUDGET);
        r.assignment.validate(&inst).unwrap();
        assert!(r.assignment.peak >= inst.lower_bound());
    }

    #[test]
    fn converges_to_the_certified_optimum_on_small_instances() {
        for seed in [13u64, 17, 19, 23] {
            let inst = random_instance(seed, 10);
            let opt = exact::solve(&inst, Duration::from_secs(5));
            assert!(opt.proved_optimal);
            let heur = bestfit::solve(&inst);
            let r = improve(&inst, &heur, Duration::from_secs(2));
            assert!(r.proved_optimal, "seed {seed}: dive should certify");
            assert_eq!(r.assignment.peak, opt.assignment.peak, "seed {seed}");
        }
    }
}
