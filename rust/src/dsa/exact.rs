//! Exact DSA solver by branch-and-bound — the in-repo substitute for the
//! CPLEX runs in §5.2 of the paper (the offline testbed has no CPLEX).
//!
//! Completeness argument: any feasible packing can be *normalized* by
//! repeatedly pushing blocks down (toward offset 0) until each block rests
//! either at 0 or directly on top of a lifetime-overlapping block; pushing
//! never increases the peak. Hence searching offsets restricted to
//! `{0} ∪ {x_j + w_j | j placed, lifetime-overlapping}` visits a superset
//! of the normalized optima and the best leaf is a global optimum.
//!
//! Pruning: (a) a node's partial peak must stay below the incumbent;
//! (b) the global liveness lower bound ends the search early when met;
//! (c) blocks are branched in decreasing-size order, which tightens (a)
//! quickly. A wall-clock time limit mirrors the paper's 1-hour CPLEX cap;
//! on timeout the incumbent (seeded with the best-fit heuristic solution)
//! is returned with `proved_optimal = false`.

use super::bestfit;
use super::problem::DsaInstance;
use super::solution::Assignment;
use std::time::{Duration, Instant};

/// Result of an exact solve attempt.
#[derive(Debug, Clone)]
pub struct ExactResult {
    pub assignment: Assignment,
    /// True when the search completed (or met the lower bound) within the
    /// time limit — i.e. the assignment is a certified optimum.
    pub proved_optimal: bool,
    /// Search nodes expanded (for Fig-4-style reporting).
    pub nodes: u64,
    pub elapsed: Duration,
}

/// Solve exactly with a time limit.
pub fn solve(inst: &DsaInstance, time_limit: Duration) -> ExactResult {
    let start = Instant::now();
    let n = inst.len();
    if n == 0 {
        return ExactResult {
            assignment: Assignment {
                offsets: Vec::new(),
                peak: 0,
            },
            proved_optimal: true,
            nodes: 0,
            elapsed: start.elapsed(),
        };
    }

    let lb = inst.lower_bound();

    // Incumbent: the heuristic solution (also the paper's comparison).
    let mut best = bestfit::solve(inst);
    if best.peak == lb {
        return ExactResult {
            assignment: best,
            proved_optimal: true,
            nodes: 0,
            elapsed: start.elapsed(),
        };
    }

    // Branch order: decreasing size, then decreasing lifetime.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_unstable_by_key(|&i| {
        let b = &inst.blocks[i];
        (std::cmp::Reverse(b.size), std::cmp::Reverse(b.lifetime()))
    });

    // Precompute lifetime-overlap adjacency in branch order.
    let overlaps: Vec<Vec<usize>> = order
        .iter()
        .enumerate()
        .map(|(k, &i)| {
            (0..k)
                .filter(|&p| inst.blocks[order[p]].overlaps(&inst.blocks[i]))
                .collect()
        })
        .collect();

    struct Ctx<'a> {
        inst: &'a DsaInstance,
        order: &'a [usize],
        overlaps: &'a [Vec<usize>],
        lb: u64,
        best: Assignment,
        nodes: u64,
        deadline: Instant,
        timed_out: bool,
    }

    fn dfs(ctx: &mut Ctx<'_>, depth: usize, offsets: &mut Vec<u64>, peak: u64) {
        ctx.nodes += 1;
        if ctx.timed_out || ctx.best.peak == ctx.lb {
            return;
        }
        if ctx.nodes % 4096 == 0 && Instant::now() >= ctx.deadline {
            ctx.timed_out = true;
            return;
        }
        if depth == ctx.order.len() {
            if peak < ctx.best.peak {
                // Scatter branch-order offsets back to block ids.
                let mut by_id = vec![0u64; ctx.inst.len()];
                for (k, &i) in ctx.order.iter().enumerate() {
                    by_id[i] = offsets[k];
                }
                ctx.best = Assignment::from_offsets(ctx.inst, by_id);
                debug_assert_eq!(ctx.best.peak, peak);
            }
            return;
        }

        let bid = ctx.order[depth];
        let b = &ctx.inst.blocks[bid];

        // Candidate offsets: 0 plus tops of overlapping placed blocks.
        let mut candidates: Vec<u64> = vec![0];
        for &p in &ctx.overlaps[depth] {
            candidates.push(offsets[p] + ctx.inst.blocks[ctx.order[p]].size);
        }
        candidates.sort_unstable();
        candidates.dedup();

        for x in candidates {
            let top = x + b.size;
            if top.max(peak) >= ctx.best.peak {
                // Candidates ascend, so all later ones prune too.
                break;
            }
            if let Some(cap) = ctx.inst.capacity {
                if top > cap {
                    break;
                }
            }
            // Feasibility vs placed overlapping blocks.
            let collides = ctx.overlaps[depth].iter().any(|&p| {
                let pb = &ctx.inst.blocks[ctx.order[p]];
                let (px, ptop) = (offsets[p], offsets[p] + pb.size);
                x < ptop && px < top
            });
            if collides {
                continue;
            }
            offsets.push(x);
            dfs(ctx, depth + 1, offsets, peak.max(top));
            offsets.pop();
            if ctx.timed_out || ctx.best.peak == ctx.lb {
                return;
            }
        }
    }

    let mut ctx = Ctx {
        inst,
        order: &order,
        overlaps: &overlaps,
        lb,
        best: best.clone(),
        nodes: 0,
        deadline: start + time_limit,
        timed_out: false,
    };
    let mut offsets = Vec::with_capacity(n);
    dfs(&mut ctx, 0, &mut offsets, 0);

    best = ctx.best;
    let proved = !ctx.timed_out;
    debug_assert!(best.validate(inst).is_ok());
    ExactResult {
        assignment: best,
        proved_optimal: proved,
        nodes: ctx.nodes,
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    const LIMIT: Duration = Duration::from_secs(10);

    #[test]
    fn trivial_instances() {
        let inst = DsaInstance::from_triples(&[(64, 0, 3)]);
        let r = solve(&inst, LIMIT);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 64);
    }

    #[test]
    fn meets_liveness_bound_when_achievable() {
        let inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)]);
        let r = solve(&inst, LIMIT);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 30);
    }

    /// Exhaustive grid search over small offsets, used to certify the
    /// branch-and-bound on random tiny instances.
    fn brute_force(inst: &DsaInstance, max_offset: u64) -> u64 {
        fn rec(inst: &DsaInstance, max_offset: u64, k: usize, offs: &mut Vec<u64>, best: &mut u64) {
            if k == inst.len() {
                let a = Assignment::from_offsets(inst, offs.clone());
                if a.validate(inst).is_ok() {
                    *best = (*best).min(a.peak);
                }
                return;
            }
            for x in 0..=max_offset {
                offs.push(x);
                rec(inst, max_offset, k + 1, offs, best);
                offs.pop();
            }
        }
        let mut best = u64::MAX;
        rec(inst, max_offset, 0, &mut Vec::new(), &mut best);
        best
    }

    #[test]
    fn matches_brute_force_on_random_tiny_instances() {
        let mut rng = Pcg32::seeded(31);
        for case in 0..25 {
            let n = rng.range_usize(2, 5);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 6);
                    (rng.range(1, 3), a, a + rng.range(1, 5))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            let bf = brute_force(&inst, inst.total_size());
            let r = solve(&inst, LIMIT);
            assert!(r.proved_optimal, "case {case} timed out");
            assert_eq!(r.assignment.peak, bf, "case {case}: {triples:?}");
            r.assignment.validate(&inst).unwrap();
        }
    }

    #[test]
    fn exact_never_exceeds_heuristic() {
        let mut rng = Pcg32::seeded(37);
        let triples: Vec<(u64, u64, u64)> = (0..14)
            .map(|_| {
                let a = rng.range(0, 30);
                (rng.range(1, 64), a, a + rng.range(1, 12))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let heur = crate::dsa::bestfit::solve(&inst);
        let r = solve(&inst, LIMIT);
        assert!(r.assignment.peak <= heur.peak);
        assert!(r.assignment.peak >= inst.lower_bound());
    }

    #[test]
    fn timeout_returns_incumbent() {
        // A dense instance with a zero time budget must still return the
        // (valid) heuristic incumbent, unproven.
        let mut rng = Pcg32::seeded(41);
        let triples: Vec<(u64, u64, u64)> = (0..40)
            .map(|_| {
                let a = rng.range(0, 50);
                (rng.range(1, 100), a, a + rng.range(1, 30))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let r = solve(&inst, Duration::from_nanos(0));
        r.assignment.validate(&inst).unwrap();
    }
}
