//! Exact DSA solver by branch-and-bound — the in-repo substitute for the
//! CPLEX runs in §5.2 of the paper (the offline testbed has no CPLEX).
//!
//! Completeness argument: any feasible packing can be *normalized* by
//! repeatedly pushing blocks down (toward offset 0) until each block rests
//! either at 0 or directly on top of a lifetime-overlapping block; pushing
//! never increases the peak. Hence searching offsets restricted to
//! `{0} ∪ {x_j + w_j | j placed, lifetime-overlapping}` visits a superset
//! of the normalized optima and the best leaf is a global optimum.
//!
//! Pruning: (a) a node's partial peak must stay below the incumbent;
//! (b) the global liveness lower bound ends the search early when met;
//! (c) blocks are branched in decreasing-size order, which tightens (a)
//! quickly. A wall-clock time limit mirrors the paper's 1-hour CPLEX cap;
//! on timeout the incumbent (seeded with the best-fit heuristic solution)
//! is returned with `proved_optimal = false`.
//!
//! The search core is exposed as [`dive`]: a bounded branch-and-bound
//! descent seeded from *any* caller-supplied incumbent, cut off by a
//! wall-clock deadline and a node budget. [`solve`] is `dive` seeded
//! from the best-fit heuristic with an unlimited node budget; the
//! anytime optimizer ([`super::anytime`]) issues short node-bounded
//! dives from its own incumbent instead.

use super::bestfit;
use super::problem::DsaInstance;
use super::solution::Assignment;
use std::time::{Duration, Instant};

/// Result of an exact solve attempt.
#[derive(Debug, Clone)]
pub struct ExactResult {
    pub assignment: Assignment,
    /// True when the search completed (or met the lower bound) within the
    /// time limit — i.e. the assignment is a certified optimum.
    pub proved_optimal: bool,
    /// Search nodes expanded (for Fig-4-style reporting).
    pub nodes: u64,
    pub elapsed: Duration,
}

/// Outcome of one bounded branch-and-bound dive (see [`dive`]).
#[derive(Debug, Clone)]
pub struct DiveResult {
    /// Best assignment found: the seed incumbent (cloned — the caller's
    /// copy is never aliased by branching scratch state) or a strictly
    /// tighter packing.
    pub assignment: Assignment,
    /// Search nodes expanded before completion or cutoff.
    pub nodes: u64,
    /// True when the search space was exhausted (or the liveness lower
    /// bound met) within the budgets — the assignment is then a
    /// certified optimum.
    pub completed: bool,
}

struct Ctx<'a> {
    inst: &'a DsaInstance,
    order: &'a [usize],
    overlaps: &'a [Vec<usize>],
    lb: u64,
    best: Assignment,
    nodes: u64,
    node_limit: u64,
    deadline: Instant,
    cut_off: bool,
}

fn dfs(ctx: &mut Ctx<'_>, depth: usize, offsets: &mut Vec<u64>, peak: u64) {
    ctx.nodes += 1;
    if ctx.cut_off || ctx.best.peak == ctx.lb {
        return;
    }
    // The deadline is polled on the very first node (so a zero budget
    // returns the untouched seed) and every 4096 nodes after; the node
    // budget is exact.
    if ctx.nodes > ctx.node_limit
        || (ctx.nodes & 4095 == 1 && Instant::now() >= ctx.deadline)
    {
        ctx.cut_off = true;
        return;
    }
    if depth == ctx.order.len() {
        if peak < ctx.best.peak {
            // Scatter branch-order offsets back to block ids.
            let mut by_id = vec![0u64; ctx.inst.len()];
            for (k, &i) in ctx.order.iter().enumerate() {
                by_id[i] = offsets[k];
            }
            ctx.best = Assignment::from_offsets(ctx.inst, by_id);
            debug_assert_eq!(ctx.best.peak, peak);
        }
        return;
    }

    let bid = ctx.order[depth];
    let b = &ctx.inst.blocks[bid];

    // Candidate offsets: 0 plus tops of overlapping placed blocks.
    let mut candidates: Vec<u64> = vec![0];
    for &p in &ctx.overlaps[depth] {
        candidates.push(offsets[p] + ctx.inst.blocks[ctx.order[p]].size);
    }
    candidates.sort_unstable();
    candidates.dedup();

    for x in candidates {
        let top = x + b.size;
        if top.max(peak) >= ctx.best.peak {
            // Candidates ascend, so all later ones prune too.
            break;
        }
        if let Some(cap) = ctx.inst.capacity {
            if top > cap {
                break;
            }
        }
        // Feasibility vs placed overlapping blocks.
        let collides = ctx.overlaps[depth].iter().any(|&p| {
            let pb = &ctx.inst.blocks[ctx.order[p]];
            let (px, ptop) = (offsets[p], offsets[p] + pb.size);
            x < ptop && px < top
        });
        if collides {
            continue;
        }
        offsets.push(x);
        dfs(ctx, depth + 1, offsets, peak.max(top));
        offsets.pop();
        if ctx.cut_off || ctx.best.peak == ctx.lb {
            return;
        }
    }
}

/// One bounded branch-and-bound dive seeded from `incumbent`.
///
/// The incumbent is **cloned before branching** — the search's scratch
/// state never mutates the caller's copy, and a cut-off dive returns an
/// exact clone of the seed. The returned assignment is always valid for
/// `inst` and never worse than the seed; `completed = true` certifies it
/// as a global optimum (search space exhausted, or the liveness lower
/// bound was already met). The dive stops at `deadline` (polled on the
/// first node and every 4096 thereafter) or after `node_limit` expanded
/// nodes, whichever comes first.
pub fn dive(
    inst: &DsaInstance,
    incumbent: &Assignment,
    deadline: Instant,
    node_limit: u64,
) -> DiveResult {
    let n = inst.len();
    debug_assert_eq!(incumbent.offsets.len(), n, "incumbent must match the instance");
    if n == 0 {
        return DiveResult {
            assignment: Assignment {
                offsets: Vec::new(),
                peak: 0,
            },
            nodes: 0,
            completed: true,
        };
    }
    let lb = inst.lower_bound();
    if incumbent.peak <= lb {
        return DiveResult {
            assignment: incumbent.clone(),
            nodes: 0,
            completed: true,
        };
    }

    // Branch order: decreasing size, then decreasing lifetime.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_unstable_by_key(|&i| {
        let b = &inst.blocks[i];
        (std::cmp::Reverse(b.size), std::cmp::Reverse(b.lifetime()))
    });

    // Precompute lifetime-overlap adjacency in branch order.
    let overlaps: Vec<Vec<usize>> = order
        .iter()
        .enumerate()
        .map(|(k, &i)| {
            (0..k)
                .filter(|&p| inst.blocks[order[p]].overlaps(&inst.blocks[i]))
                .collect()
        })
        .collect();

    let mut ctx = Ctx {
        inst,
        order: &order,
        overlaps: &overlaps,
        lb,
        best: incumbent.clone(),
        nodes: 0,
        node_limit,
        deadline,
        cut_off: false,
    };
    let mut offsets = Vec::with_capacity(n);
    dfs(&mut ctx, 0, &mut offsets, 0);

    debug_assert!(ctx.best.validate(inst).is_ok());
    DiveResult {
        assignment: ctx.best,
        nodes: ctx.nodes,
        completed: !ctx.cut_off,
    }
}

/// Solve exactly with a time limit.
///
/// Every exit path — empty instance, lower bound met by the heuristic
/// seed, completed search, timeout — reports `nodes` as the actual
/// expansion count (0 when no branching happened) and `elapsed` as the
/// wall time from entry.
pub fn solve(inst: &DsaInstance, time_limit: Duration) -> ExactResult {
    let start = Instant::now();
    // Incumbent: the heuristic solution (also the paper's comparison).
    let seed = bestfit::solve(inst);
    let d = dive(inst, &seed, start + time_limit, u64::MAX);
    ExactResult {
        assignment: d.assignment,
        proved_optimal: d.completed,
        nodes: d.nodes,
        elapsed: start.elapsed(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    const LIMIT: Duration = Duration::from_secs(10);

    #[test]
    fn trivial_instances() {
        let inst = DsaInstance::from_triples(&[(64, 0, 3)]);
        let r = solve(&inst, LIMIT);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 64);
    }

    #[test]
    fn meets_liveness_bound_when_achievable() {
        let inst = DsaInstance::from_triples(&[(10, 0, 4), (20, 2, 6), (5, 5, 7)]);
        let r = solve(&inst, LIMIT);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 30);
    }

    #[test]
    fn empty_instance_reports_consistent_counters() {
        // Regression: the empty path must look exactly like any other
        // no-branching exit — proved, zero nodes, elapsed recorded.
        let inst = DsaInstance::from_triples(&[]);
        let r = solve(&inst, LIMIT);
        assert!(r.proved_optimal);
        assert_eq!(r.assignment.peak, 0);
        assert!(r.assignment.offsets.is_empty());
        assert_eq!(r.nodes, 0);
        assert!(r.elapsed <= LIMIT);
        // Same contract through the bounded entry.
        let d = dive(&inst, &r.assignment, Instant::now() + LIMIT, u64::MAX);
        assert!(d.completed);
        assert_eq!((d.nodes, d.assignment.peak), (0, 0));
    }

    /// Exhaustive grid search over small offsets, used to certify the
    /// branch-and-bound on random tiny instances.
    fn brute_force(inst: &DsaInstance, max_offset: u64) -> u64 {
        fn rec(inst: &DsaInstance, max_offset: u64, k: usize, offs: &mut Vec<u64>, best: &mut u64) {
            if k == inst.len() {
                let a = Assignment::from_offsets(inst, offs.clone());
                if a.validate(inst).is_ok() {
                    *best = (*best).min(a.peak);
                }
                return;
            }
            for x in 0..=max_offset {
                offs.push(x);
                rec(inst, max_offset, k + 1, offs, best);
                offs.pop();
            }
        }
        let mut best = u64::MAX;
        rec(inst, max_offset, 0, &mut Vec::new(), &mut best);
        best
    }

    #[test]
    fn matches_brute_force_on_random_tiny_instances() {
        let mut rng = Pcg32::seeded(31);
        for case in 0..25 {
            let n = rng.range_usize(2, 5);
            let triples: Vec<(u64, u64, u64)> = (0..n)
                .map(|_| {
                    let a = rng.range(0, 6);
                    (rng.range(1, 3), a, a + rng.range(1, 5))
                })
                .collect();
            let inst = DsaInstance::from_triples(&triples);
            let bf = brute_force(&inst, inst.total_size());
            let r = solve(&inst, LIMIT);
            assert!(r.proved_optimal, "case {case} timed out");
            assert_eq!(r.assignment.peak, bf, "case {case}: {triples:?}");
            r.assignment.validate(&inst).unwrap();
        }
    }

    #[test]
    fn exact_never_exceeds_heuristic() {
        let mut rng = Pcg32::seeded(37);
        let triples: Vec<(u64, u64, u64)> = (0..14)
            .map(|_| {
                let a = rng.range(0, 30);
                (rng.range(1, 64), a, a + rng.range(1, 12))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let heur = crate::dsa::bestfit::solve(&inst);
        let r = solve(&inst, LIMIT);
        assert!(r.assignment.peak <= heur.peak);
        assert!(r.assignment.peak >= inst.lower_bound());
    }

    #[test]
    fn timeout_returns_the_heuristic_incumbent_unproven() {
        // Regression: a zero time budget must cut off on the very first
        // node — before any improvement — and return the (valid)
        // best-fit seed byte-for-byte, with proved_optimal = false and
        // the node/elapsed counters still populated.
        let mut rng = Pcg32::seeded(41);
        let triples: Vec<(u64, u64, u64)> = (0..40)
            .map(|_| {
                let a = rng.range(0, 50);
                (rng.range(1, 100), a, a + rng.range(1, 30))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let seed = bestfit::solve(&inst);
        assert!(seed.peak > inst.lower_bound(), "instance must not be lb-tight");
        let r = solve(&inst, Duration::from_nanos(0));
        r.assignment.validate(&inst).unwrap();
        assert!(!r.proved_optimal);
        assert_eq!(r.assignment.offsets, seed.offsets, "incumbent is the seed");
        assert_eq!(r.assignment.peak, seed.peak);
        assert!(r.nodes >= 1, "the cutoff node itself is counted");
    }

    #[test]
    fn dive_clones_the_seed_and_never_worsens_it() {
        // Regression: branching scratch state must not alias the
        // caller's incumbent — a cut-off dive hands back an exact clone
        // and leaves the original untouched.
        let mut rng = Pcg32::seeded(43);
        let triples: Vec<(u64, u64, u64)> = (0..12)
            .map(|_| {
                let a = rng.range(0, 20);
                (rng.range(1, 32), a, a + rng.range(1, 10))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let seed = bestfit::solve(&inst);
        let before = seed.clone();
        let cut = dive(&inst, &seed, Instant::now(), u64::MAX);
        assert_eq!(seed.offsets, before.offsets, "seed untouched by the dive");
        assert_eq!(seed.peak, before.peak);
        assert_eq!(cut.assignment.offsets, seed.offsets, "cut-off dive = clone");
        let full = dive(&inst, &seed, Instant::now() + LIMIT, u64::MAX);
        assert!(full.completed);
        assert!(full.assignment.peak <= seed.peak);
        assert!(full.assignment.validate(&inst).is_ok());
        assert_eq!(seed.offsets, before.offsets, "seed untouched by a full dive");
    }

    #[test]
    fn dive_respects_the_node_budget() {
        let mut rng = Pcg32::seeded(47);
        let triples: Vec<(u64, u64, u64)> = (0..30)
            .map(|_| {
                let a = rng.range(0, 40);
                (rng.range(1, 80), a, a + rng.range(1, 20))
            })
            .collect();
        let inst = DsaInstance::from_triples(&triples);
        let seed = bestfit::solve(&inst);
        let d = dive(&inst, &seed, Instant::now() + LIMIT, 64);
        assert!(!d.completed, "a 30-block search cannot finish in 64 nodes");
        assert!(d.nodes <= 65, "budget is exact (+1 for the cutoff node)");
        assert!(d.assignment.peak <= seed.peak);
        assert!(d.assignment.validate(&inst).is_ok());
    }
}
