"""L1 correctness: the Pallas matmul kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and dtypes; every case asserts allclose. This is
the CORE correctness signal for the AOT stack — if the kernel is right
here, the lowered HLO the Rust runtime executes is right too (same HLO).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as pk
from compile.kernels import ref

DIMS = st.sampled_from([1, 2, 3, 5, 8, 16, 31, 64, 100, 128, 200, 256])
DTYPES = st.sampled_from([np.float32, np.float16])


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


def _tol(dtype):
    # fp32 matmuls differ from the oracle only by accumulation order.
    return dict(rtol=2e-2, atol=2e-2) if dtype == np.float16 else dict(rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, dtype=DTYPES, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, dtype, seed):
    rng = np.random.default_rng(seed)
    x, w = _rand(rng, (m, k), dtype), _rand(rng, (k, n), dtype)
    np.testing.assert_allclose(
        np.asarray(pk.matmul(x, w)), np.asarray(ref.matmul(x, w)), **_tol(dtype)
    )


@settings(max_examples=30, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, fuse=st.booleans(), seed=st.integers(0, 2**16))
def test_bias_relu_fusion_matches_ref(m, k, n, fuse, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (m, k), np.float32)
    w = _rand(rng, (k, n), np.float32)
    b = _rand(rng, (n,), np.float32)
    got = np.asarray(pk.matmul(x, w, b, fuse_relu=fuse))
    want = np.asarray(ref.matmul(x, w, b, fuse_relu=fuse))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    if fuse:
        assert (got >= 0).all()


def test_relu_actually_clamps():
    x = jnp.asarray([[-1.0, 2.0]], jnp.float32)
    w = jnp.eye(2, dtype=jnp.float32)
    out = np.asarray(pk.matmul(x, w, fuse_relu=True))
    np.testing.assert_allclose(out, [[0.0, 2.0]])


def test_fp32_accumulation_of_fp16_inputs():
    # Summing many small fp16 values overflows fp16 accumulation but not
    # fp32; the kernel must accumulate in fp32 like the oracle.
    k = 2048
    x = jnp.full((1, k), 0.25, jnp.float16)
    w = jnp.full((k, 1), 0.25, jnp.float16)
    got = np.asarray(pk.matmul(x, w)).astype(np.float32)
    np.testing.assert_allclose(got, [[k * 0.0625]], rtol=1e-3)


def test_tile_helper_divides():
    for dim in [1, 7, 128, 200, 1000]:
        t = pk._tile(dim, 128)
        assert 1 <= t <= min(dim, 128) and dim % t == 0


def test_vmem_estimate_within_budget():
    # Default tiles must fit a TPU core's VMEM (16 MiB) with double
    # buffering — the §Perf structural check for interpret-mode kernels.
    assert pk.vmem_bytes() < 16 * 2**20


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (128, 128, 128), (256, 784, 10)])
def test_known_shapes_exact(m, k, n):
    rng = np.random.default_rng(0)
    x, w = _rand(rng, (m, k), np.float32), _rand(rng, (k, n), np.float32)
    np.testing.assert_allclose(
        np.asarray(pk.matmul(x, w)), np.asarray(ref.matmul(x, w)), rtol=1e-4, atol=1e-4
    )
