"""L2 correctness: model numerics, training dynamics, and AOT lowering."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

SMALL = (12, 16, 10)  # tiny layer config for fast tests


def test_init_params_shapes():
    params = model.init_params(0, SMALL)
    assert len(params) == 4
    assert params[0].shape == (12, 16)
    assert params[1].shape == (16,)
    assert params[2].shape == (16, 10)
    assert params[3].shape == (10,)


def test_predict_matches_pure_jnp_oracle():
    params = model.init_params(1, SMALL)
    x, _ = model.synthetic_batch(0, 8, SMALL)
    got = np.asarray(model.predict(*params, x))
    want = np.asarray(model.predict_ref(*params, x))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_loss_is_finite_positive():
    params = model.init_params(2, SMALL)
    x, y = model.synthetic_batch(1, 8, SMALL)
    val = float(model.loss(*params, x, y))
    assert np.isfinite(val) and val > 0


def test_train_step_decreases_loss():
    params = model.init_params(3, SMALL)
    x, y = model.synthetic_batch(2, 32, SMALL)
    first = float(model.loss(*params, x, y))
    for _ in range(30):
        *params, _l = model.train_step(*params, x, y)
        params = tuple(params)
    last = float(model.loss(*params, x, y))
    assert last < first * 0.8, f"{first} -> {last}"


def test_train_step_preserves_shapes():
    params = model.init_params(4, SMALL)
    x, y = model.synthetic_batch(3, 8, SMALL)
    out = model.train_step(*params, x, y)
    assert len(out) == len(params) + 1
    for p, q in zip(params, out[:-1]):
        assert p.shape == q.shape and p.dtype == q.dtype
    assert out[-1].shape == ()


def test_synthetic_batch_is_deterministic_and_learnable():
    x1, y1 = model.synthetic_batch(7, 16, SMALL)
    x2, y2 = model.synthetic_batch(7, 16, SMALL)
    np.testing.assert_array_equal(np.asarray(x1), np.asarray(x2))
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert y1.shape == (16, 10)
    np.testing.assert_allclose(np.asarray(y1.sum(axis=-1)), 1.0)


def test_aot_lowering_produces_hlo_text():
    entries = list(aot.lower_all(SMALL))
    names = [e[0] for e in entries]
    assert any(n.startswith("train_step") for n in names)
    assert any(n.startswith("predict") for n in names)
    for _name, text, sig in entries:
        assert text.startswith("HloModule"), text[:40]
        assert len(sig) >= len(model._unflatten(model.init_params(0, SMALL)) * 2)


def test_lowered_train_step_runs_and_matches_eager():
    """Compile the AOT-lowered computation and compare with eager
    execution; the HLO *text* numerics are verified end-to-end on the
    Rust side (rust/tests), which loads these exact artifacts."""
    params = model.init_params(5, SMALL)
    x, y = model.synthetic_batch(4, 8, SMALL)
    lowered = jax.jit(model.train_step).lower(
        *[jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params],
        jax.ShapeDtypeStruct(x.shape, x.dtype),
        jax.ShapeDtypeStruct(y.shape, y.dtype),
    )
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")
    # The text must carry the full entry signature (params + x + y inputs).
    assert text.count("parameter(") >= len(params) + 2

    got = lowered.compile()(*params, x, y)
    want = model.train_step(*params, x, y)
    assert len(got) == len(want)
    for g, w in zip(got, want):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-4, atol=1e-5
        )


@pytest.mark.parametrize("batch", [1, 32])
def test_predict_batch_shapes(batch):
    params = model.init_params(6, SMALL)
    x = jnp.zeros((batch, SMALL[0]), jnp.float32)
    assert model.predict(*params, x).shape == (batch, SMALL[-1])


def test_predict_proba_is_softmax_of_logits():
    params = model.init_params(8, SMALL)
    x, _ = model.synthetic_batch(9, 4, SMALL)
    probs = np.asarray(model.predict_proba(*params, x))
    logits = np.asarray(model.predict(*params, x))
    want = np.exp(logits - logits.max(-1, keepdims=True))
    want = want / want.sum(-1, keepdims=True)
    np.testing.assert_allclose(probs, want, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(probs.sum(-1), 1.0, rtol=1e-5)
