"""Hypothesis sweep of the Pallas softmax kernel vs its oracle."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import softmax as sk

DIMS = st.sampled_from([1, 2, 3, 7, 10, 16, 40, 100, 128, 200])


@settings(max_examples=40, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**16), scale=st.sampled_from([1.0, 10.0, 100.0]))
def test_softmax_matches_ref(m, n, seed, scale):
    rng = np.random.default_rng(seed)
    x = jnp.asarray((rng.standard_normal((m, n)) * scale).astype(np.float32))
    got = np.asarray(sk.softmax(x))
    want = np.asarray(sk.softmax_ref(x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_rows_sum_to_one_and_nonnegative():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64, 10)).astype(np.float32))
    p = np.asarray(sk.softmax(x))
    assert (p >= 0).all()
    np.testing.assert_allclose(p.sum(axis=-1), 1.0, rtol=1e-5)


def test_numerically_stable_for_large_logits():
    # Naive exp overflows at ~88.7 in fp32; max-shifting must not.
    x = jnp.asarray([[1000.0, 1000.0, 0.0]], jnp.float32)
    p = np.asarray(sk.softmax(x))
    assert np.isfinite(p).all()
    np.testing.assert_allclose(p[0, :2], 0.5, rtol=1e-5)
    assert p[0, 2] < 1e-30


def test_invariant_to_constant_shift():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((8, 12)).astype(np.float32))
    a = np.asarray(sk.softmax(x))
    b = np.asarray(sk.softmax(x + 123.0))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)
