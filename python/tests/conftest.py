"""Make `pytest python/tests/` work from the repository root by putting
the `python/` package directory (where `compile` lives) on sys.path."""

import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))
