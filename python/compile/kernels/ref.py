"""Pure-jnp oracle for the Pallas kernels — the correctness ground truth.

Every kernel in :mod:`compile.kernels` must match its function here to
float tolerance for all shapes/dtypes the test sweep generates.
"""

import jax.numpy as jnp


def matmul(x, w, bias=None, *, fuse_relu: bool = False):
    """Reference ``relu?(x @ w + bias?)`` with fp32 accumulation."""
    acc = jnp.matmul(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    if bias is not None:
        acc = acc + bias.astype(jnp.float32)
    if fuse_relu:
        acc = jnp.maximum(acc, 0.0)
    return acc.astype(jnp.promote_types(x.dtype, w.dtype))
