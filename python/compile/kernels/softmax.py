"""Layer-1 Pallas kernel: numerically-stable row-wise softmax.

Used by the serving path's probability head (`model.predict_proba`).
Tiled by rows: each grid instance owns a `bm × N` band, computes
max-shifted exponentials and normalizes in fp32 — the standard
three-pass-fused-to-one softmax, expressed with TPU-friendly row bands
instead of CUDA warp shuffles (DESIGN.md §Hardware-Adaptation).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BM = 128


def _softmax_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    m = jnp.max(x, axis=-1, keepdims=True)
    e = jnp.exp(x - m)
    o_ref[...] = (e / jnp.sum(e, axis=-1, keepdims=True)).astype(o_ref.dtype)


def _rows_tile(m: int) -> int:
    t = min(m, BM)
    while m % t:
        t -= 1
    return t


@jax.jit
def softmax(x):
    """Row-wise softmax over the last axis of a 2-D array."""
    m, n = x.shape
    bm = _rows_tile(m)
    return pl.pallas_call(
        _softmax_kernel,
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)


@functools.partial(jax.jit, static_argnames=())
def softmax_ref(x):
    """Oracle: jax.nn.softmax in fp32."""
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)
