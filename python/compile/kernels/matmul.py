"""Layer-1 Pallas kernel: tiled matmul with optional fused bias + ReLU.

This is the compute hot-spot of the L2 model (every linear layer of the
MLP classifier goes through it). The tiling is written for TPU-style
execution even though this repository runs it under ``interpret=True`` on
the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom call the CPU
plugin cannot execute — see DESIGN.md §Hardware-Adaptation):

* the grid is ``(M/bm, N/bn)``; each program instance owns one
  ``bm × bn`` output tile — the MXU-shaped unit of work;
* the K dimension is looped *inside* the kernel body over ``bk``-wide
  slices of the operand tiles, accumulating in fp32 — the classic
  VMEM-resident accumulator pattern (``bm*bk + bk*bn + bm*bn`` floats per
  instance; 128³ tiles ≈ 192 KiB, comfortably within a TPU core's
  ~16 MiB VMEM with room for double buffering);
* ``BlockSpec`` index maps express the HBM→VMEM schedule that a CUDA
  kernel would express with threadblock tiling.

Correctness oracle: :mod:`compile.kernels.ref` (pure jnp), swept by
hypothesis in ``python/tests/test_kernel.py``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes (MXU-friendly).
BM, BN, BK = 128, 128, 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, bk: int, fuse_relu: bool):
    """One (bm × bn) output tile; K is looped in bk-wide slices."""
    bm, k = x_ref.shape
    _, bn = w_ref.shape
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    # K is static at trace time, so this unrolls into an MXU-sized chain.
    for s in range(0, k, bk):
        xs = x_ref[:, s : s + bk].astype(jnp.float32)
        ws = w_ref[s : s + bk, :].astype(jnp.float32)
        acc += xs @ ws
    if fuse_relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _bias_matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, bk: int, fuse_relu: bool):
    bm, k = x_ref.shape
    _, bn = w_ref.shape
    acc = jnp.zeros((bm, bn), dtype=jnp.float32)
    for s in range(0, k, bk):
        xs = x_ref[:, s : s + bk].astype(jnp.float32)
        ws = w_ref[s : s + bk, :].astype(jnp.float32)
        acc += xs @ ws
    acc += b_ref[...].astype(jnp.float32)
    if fuse_relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc.astype(o_ref.dtype)


def _tile(dim: int, block: int) -> int:
    """Largest tile ≤ block that divides dim (dims here are powers of two
    or small; worst case degenerates to 1 which is still correct)."""
    t = min(dim, block)
    while dim % t:
        t -= 1
    return t


def _matmul_impl(x, w, bias=None, *, fuse_relu: bool = False):
    """``relu?(x @ w + bias?)`` via the tiled Pallas kernel.

    ``x: [M, K]``, ``w: [K, N]``, ``bias: [N] | None``. Any M/K/N works;
    tiles shrink to the largest divisor ≤ the default block size.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm, bn, bk = _tile(m, BM), _tile(n, BN), _tile(k, BK)
    grid = (m // bm, n // bn)
    out_dtype = jnp.promote_types(x.dtype, w.dtype)

    x_spec = pl.BlockSpec((bm, k), lambda i, j: (i, 0))
    w_spec = pl.BlockSpec((k, bn), lambda i, j: (0, j))
    o_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))

    if bias is None:
        kernel = functools.partial(_matmul_kernel, bk=bk, fuse_relu=fuse_relu)
        in_specs = [x_spec, w_spec]
        args = (x, w)
    else:
        assert bias.shape == (n,), f"bias shape {bias.shape} != ({n},)"
        kernel = functools.partial(_bias_matmul_kernel, bk=bk, fuse_relu=fuse_relu)
        b_spec = pl.BlockSpec((bn,), lambda i, j: (j,))
        in_specs = [x_spec, w_spec, b_spec]
        args = (x, w, bias)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(*args)


# ---------------------------------------------------------------------------
# Reverse-mode autodiff: pallas_call has no built-in transpose rule, so the
# backward pass is spelled out — as more Pallas matmuls, keeping the L1
# kernel on the gradient path too (dx = g·Wᵀ, dW = xᵀ·g, db = Σg).
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _mm(x, w, fuse_relu):
    return _matmul_impl(x, w, None, fuse_relu=fuse_relu)


def _mm_fwd(x, w, fuse_relu):
    out = _matmul_impl(x, w, None, fuse_relu=fuse_relu)
    return out, (x, w, out if fuse_relu else None)


def _mm_bwd(fuse_relu, res, g):
    x, w, out = res
    if fuse_relu:
        g = g * (out > 0).astype(g.dtype)
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    return dx.astype(x.dtype), dw.astype(w.dtype)


_mm.defvjp(_mm_fwd, _mm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _mm_bias(x, w, b, fuse_relu):
    return _matmul_impl(x, w, b, fuse_relu=fuse_relu)


def _mm_bias_fwd(x, w, b, fuse_relu):
    out = _matmul_impl(x, w, b, fuse_relu=fuse_relu)
    return out, (x, w, out if fuse_relu else None)


def _mm_bias_bwd(fuse_relu, res, g):
    x, w, out = res
    if fuse_relu:
        g = g * (out > 0).astype(g.dtype)
    dx = _matmul_impl(g, w.T)
    dw = _matmul_impl(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx.astype(x.dtype), dw.astype(w.dtype), db.astype(g.dtype)


_mm_bias.defvjp(_mm_bias_fwd, _mm_bias_bwd)


@functools.partial(jax.jit, static_argnames=("fuse_relu",))
def matmul(x, w, bias=None, *, fuse_relu: bool = False):
    """Public entry: differentiable fused matmul (see `_matmul_impl`)."""
    if bias is None:
        return _mm(x, w, fuse_relu)
    return _mm_bias(x, w, bias, fuse_relu)


def vmem_bytes(bm: int = BM, bn: int = BN, bk: int = BK, bytes_per_el: int = 4) -> int:
    """Estimated VMEM working set of one grid instance (perf reporting)."""
    return bytes_per_el * (bm * bk + bk * bn + bm * bn) * 2  # ×2: double buffer
