"""Layer-2 JAX model: an MLP classifier whose linear layers run through
the Layer-1 Pallas matmul kernel.

The Rust coordinator trains/serves this model through PJRT using the HLO
artifacts :mod:`compile.aot` lowers from the functions here; Python never
runs on the request path. Parameters travel as a flat tuple so the HLO
entry signature is stable and easy to drive from Rust.

Functions
---------
``init_params(rng, layer_sizes)``       → tuple of (W, b) arrays, flattened
``predict(params..., x)``               → logits
``loss(params..., x, y)``               → scalar cross-entropy
``train_step(params..., x, y)``         → (new_params..., loss)  [SGD]
"""

import jax
import jax.numpy as jnp

from compile.kernels import matmul as pk

# Default architecture: 784 (28×28 synthetic digits) → 256 → 128 → 10.
LAYER_SIZES = (784, 256, 128, 10)
LEARNING_RATE = 0.05


def n_layers(layer_sizes=LAYER_SIZES) -> int:
    return len(layer_sizes) - 1


def init_params(seed: int = 0, layer_sizes=LAYER_SIZES):
    """He-initialized weights, zero biases, flattened as (W0,b0,W1,b1,…)."""
    params = []
    key = jax.random.PRNGKey(seed)
    for i, (fan_in, fan_out) in enumerate(zip(layer_sizes[:-1], layer_sizes[1:])):
        key, sub = jax.random.split(key)
        scale = jnp.sqrt(2.0 / fan_in)
        params.append(jax.random.normal(sub, (fan_in, fan_out), jnp.float32) * scale)
        params.append(jnp.zeros((fan_out,), jnp.float32))
        del i
    return tuple(params)


def _unflatten(flat):
    assert len(flat) % 2 == 0
    return [(flat[2 * i], flat[2 * i + 1]) for i in range(len(flat) // 2)]


def predict(*args):
    """``predict(W0, b0, …, Wn, bn, x)`` → logits ``[batch, classes]``.

    Hidden layers use the fused matmul+bias+ReLU kernel; the output layer
    the fused matmul+bias.
    """
    *flat, x = args
    layers = _unflatten(tuple(flat))
    h = x
    for w, b in layers[:-1]:
        h = pk.matmul(h, w, b, fuse_relu=True)
    w, b = layers[-1]
    return pk.matmul(h, w, b, fuse_relu=False)


def predict_proba(*args):
    """``predict_proba(params..., x)`` → class probabilities, via the L1
    Pallas softmax kernel (the serving path's probability head)."""
    from compile.kernels import softmax as sk

    return sk.softmax(predict(*args))


def loss(*args):
    """``loss(params..., x, y_onehot)`` → mean softmax cross-entropy."""
    *flat, x, y = args
    logits = predict(*flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.sum(y * logp, axis=-1))


def train_step(*args):
    """One SGD step: ``(params..., x, y)`` → ``(new_params..., loss)``."""
    *flat, x, y = args
    val, grads = jax.value_and_grad(
        lambda *p: loss(*p, x, y), argnums=tuple(range(len(flat)))
    )(*flat)
    new = tuple(p - LEARNING_RATE * g for p, g in zip(flat, grads))
    return (*new, val)


# ---------------------------------------------------------------------------
# Pure-jnp oracle of the whole model (kernel-free), for numeric testing.
# ---------------------------------------------------------------------------


def predict_ref(*args):
    from compile.kernels import ref

    *flat, x = args
    layers = _unflatten(tuple(flat))
    h = x
    for w, b in layers[:-1]:
        h = ref.matmul(h, w, b, fuse_relu=True)
    w, b = layers[-1]
    return ref.matmul(h, w, b, fuse_relu=False)


def synthetic_batch(seed: int, batch: int, layer_sizes=LAYER_SIZES):
    """Deterministic synthetic classification data: the label is a linear
    projection of the input pushed through argmax — learnable, non-trivial.
    """
    key = jax.random.PRNGKey(seed)
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (batch, layer_sizes[0]), jnp.float32)
    w_true = jax.random.normal(kw, (layer_sizes[0], layer_sizes[-1]), jnp.float32)
    labels = jnp.argmax(x @ w_true, axis=-1)
    y = jax.nn.one_hot(labels, layer_sizes[-1], dtype=jnp.float32)
    return x, y
