"""AOT lowering: JAX (L2) + Pallas (L1) → HLO *text* artifacts for the
Rust PJRT runtime.

HLO text — not ``serialize()``d protos — is the interchange format: jax
≥ 0.5 emits 64-bit instruction ids that the ``xla`` crate's bundled
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to ``--out-dir`` (default ``../artifacts``):

* ``train_step_b{B}.hlo.txt`` — one SGD step, per configured batch size
* ``predict_b{B}.hlo.txt``   — forward pass
* ``meta.json``              — entry signatures (shapes/dtypes, in order)
  so the Rust side can build input literals without guessing
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

TRAIN_BATCHES = (32,)
# One predict artifact per serving batch bucket: the Rust PlanRegistry
# routes each request batch to the smallest covering bucket, so the
# ladder here must match ServeConfig's default bucket ladder.
PREDICT_BATCHES = (32, 16, 8, 4, 1)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _param_specs(layer_sizes):
    specs = []
    for fan_in, fan_out in zip(layer_sizes[:-1], layer_sizes[1:]):
        specs.append(jax.ShapeDtypeStruct((fan_in, fan_out), jnp.float32))
        specs.append(jax.ShapeDtypeStruct((fan_out,), jnp.float32))
    return specs


def lower_all(layer_sizes=model.LAYER_SIZES):
    """Yield (artifact_name, hlo_text, signature) for every variant."""
    params = _param_specs(layer_sizes)
    classes = layer_sizes[-1]
    for b in TRAIN_BATCHES:
        x = jax.ShapeDtypeStruct((b, layer_sizes[0]), jnp.float32)
        y = jax.ShapeDtypeStruct((b, classes), jnp.float32)
        lowered = jax.jit(model.train_step).lower(*params, x, y)
        sig = [list(s.shape) for s in (*params, x, y)]
        yield f"train_step_b{b}", to_hlo_text(lowered), sig
    for b in PREDICT_BATCHES:
        x = jax.ShapeDtypeStruct((b, layer_sizes[0]), jnp.float32)
        lowered = jax.jit(model.predict).lower(*params, x)
        sig = [list(s.shape) for s in (*params, x)]
        yield f"predict_b{b}", to_hlo_text(lowered), sig
    # Probability head (L1 Pallas softmax on the logits), single-input.
    x1 = jax.ShapeDtypeStruct((1, layer_sizes[0]), jnp.float32)
    lowered = jax.jit(model.predict_proba).lower(*params, x1)
    yield "predict_proba_b1", to_hlo_text(lowered), [list(s.shape) for s in (*params, x1)]


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    meta = {
        "layer_sizes": list(model.LAYER_SIZES),
        "learning_rate": model.LEARNING_RATE,
        "entries": {},
    }
    for name, text, sig in lower_all():
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["entries"][name] = {"inputs": sig}
        print(f"wrote {path} ({len(text)} chars, {len(sig)} inputs)")

    with open(os.path.join(args.out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=2, sort_keys=True)
    print(f"wrote {os.path.join(args.out_dir, 'meta.json')}")


if __name__ == "__main__":
    main()
